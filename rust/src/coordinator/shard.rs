//! Multi-engine sharding: request-level parallelism across N independent
//! decode engines ("shards"), each running its continuous-batching loop
//! on its own OS thread with its own KV pool and staging arena.
//!
//! Engines are deliberately **not** `Send` (the PJRT engine holds
//! `Rc<Runtime>`), so each shard thread *constructs* its own engine from
//! a `Send + Sync` factory and the engine never crosses a thread
//! boundary. Requests flow through **shared per-shard overflow queues**
//! (bounded by `queue_depth`) with a control channel per shard for
//! wakeups, cancellation, and shutdown; token events and completions fan
//! in over one mpsc channel:
//!
//! ```text
//!            submit ──► router (least-loaded + affinity, bounded)
//!                │ push + Wake             │ all shards full
//!                ▼                         ▼
//!        overflow queues            SubmitOutcome::Rejected
//!     ┌────────┬───┴────┬────────┐   (front-end replies "overloaded")
//!  shard 0  shard 1  shard 2  shard 3          (threads)
//!  Engine   Engine   Engine   Engine
//!     └──← an idle shard steals from the most-loaded queue ←──┘
//!                │ ShardEvent::Done(Completion)
//!        poll / drain ──► caller
//! ```
//!
//! **Admission backpressure**: each shard holds at most
//! `batch + queue_depth` requests (active + queued). When every shard is
//! at capacity, [`EngineGroup::submit`] returns
//! [`SubmitOutcome::Rejected`] instead of enqueueing unboundedly — the
//! front-end turns that into a structured `overloaded` reply.
//!
//! **Memory-planned admission**: each shard also carries a
//! [`MemoryPlan`] budgeting KV pages against the engine's reported
//! [`PageGeometry`]. `submit` projects a request's *peak* page demand
//! (prompt + `max_new`, page-rounded) and reserves it against the target
//! shard's plan; when count headroom exists but no shard's page budget
//! fits, the outcome is [`SubmitOutcome::Deferred`] (retry later —
//! memory, not compute, is the bottleneck), distinct from `Rejected`.
//! Reservations follow the request across steals and cancel-removals
//! with the same under-lock transfer discipline as load accounting, and
//! release when the completion flows back.
//!
//! **Priority preemption**: requests carry a [`Priority`]; when an
//! engine is full and a strictly-higher-priority request waits in the
//! overflow queue, the shard loop force-feeds it into the engine (see
//! [`DecodeEngine::min_priority`]) so the engine can preempt its weakest
//! occupant at a step boundary. Preempted requests requeue inside the
//! engine carrying their partial generation; [`GroupEvent::Preempted`]
//! surfaces the event to streaming front-ends.
//!
//! **Work stealing**: requests wait in shared `Mutex<VecDeque>` overflow
//! queues rather than private channels, so a shard with free batch slots
//! and an empty queue of its own pulls work from the most-loaded shard's
//! queue. Routing still prefers the request's *affinity shard* while
//! that shard's load is within `affinity_slack` of the fleet minimum —
//! stealing only rebalances what affinity left queued. The affinity key
//! is the **prefix-affinity hash**: the rolling chain hash of the
//! prompt's first page-sized block (the whole prompt when the engine
//! does no token paging), so requests sharing a cacheable first block
//! land on the shard whose prefix cache is warm for it. With
//! [`GroupConfig::prefix_routing`] on, the router additionally keeps an
//! advisory per-shard memory of prefix blocks it has routed and
//! *discounts* a repeat request's page reservation by the pages its warm
//! leading blocks already hold on that shard
//! ([`PageGeometry::prefix_discount`]) — shared pages are charged once,
//! so a prefix-heavy workload stops deferring on phantom demand. Warm
//! leading blocks also *widen* the affinity window (each cached block is
//! prefill work any other shard would redo), and a request placed on its
//! prefix-affinity shard is marked **sticky**: thieves skip it, so
//! stealing never separates a request from the cached blocks it shares.
//! With content-deterministic engines (greedy decoding; see `SimEngine`)
//! per-request output is independent of placement, so stealing cannot
//! change completions — `rust/tests/serving.rs` pins that property.
//!
//! **In-flight control**: [`EngineGroup::cancel`] marks the id in a
//! shared cancel set and broadcasts to every shard (stealing means a
//! queued request can live anywhere). The owning engine stops the
//! request at its next step boundary — freeing its slot and KV pages —
//! and a still-queued request is removed from its overflow queue with
//! the same load-transfer discipline stealing uses; the submit-time set
//! check closes the pop-vs-cancel race. Token-level events
//! ([`GroupEvent::Token`]) ride the completion channel for requests
//! submitted with `Request::stream`, giving the front-end streamed
//! deltas without a second fan-in path — and costing non-streaming
//! traffic nothing per token. Deadline-expired requests are pulled out
//! of the overflow queues even while every slot is busy, so their
//! replies land at the deadline instead of whenever a slot frees.
//!
//! **Lanes (multi-reactor fan-out)**: with [`GroupConfig::lanes`] > 1
//! the single completion channel becomes one channel per *lane*, and
//! [`EngineGroup::into_lanes`] splits the group into per-lane views that
//! can move to their own front-end reactor threads. Ownership is by id:
//! a lane submits only requests whose `id % lanes` equals its lane
//! index, and shards route every event for an id to its owning lane —
//! so per-request event ordering, the load/reservation discipline, and
//! the router's shared state are untouched; only the fan-in is
//! partitioned. Each lane may register an eventfd
//! ([`EngineGroup::register_wake`]) that shards signal after every event
//! send, letting a reactor parked in `epoll_wait` see completions at
//! syscall latency instead of a poll tick. The router breaks
//! least-loaded ties toward the submitting lane's shard subset
//! (`shard % lanes == lane`) for locality; prefix affinity is computed
//! from the prompt hash as before, so placement-visible routing is
//! independent of which reactor accepted the connection.
//!
//! **Shard supervision**: a shard thread that *dies* (panic unwind —
//! `AliveGuard` clears its `alive` flag) or *wedges* (its per-loop
//! heartbeat counter stalls past [`GroupConfig::wedge_timeout`]) is
//! circuit-broken out of `route` immediately. The supervisor — driven
//! opportunistically from `submit`/`poll_event` under a try-locked
//! mutex, no dedicated thread — then **rescues** the shard's requests:
//! queued ones move to live shards with the usual load/reservation
//! transfer discipline, and a dead shard's *in-flight* ones are rebuilt
//! from their **rescue records** (the tokens the shard already emitted
//! toward the client, recorded at send time) and re-submitted as resume
//! replays, so a streaming client sees a bit-identical, gapless token
//! stream across the crash. The dead shard's page ledger is reclaimed
//! and the thread is **respawned** from the retained factory with
//! exponential backoff, up to [`GroupConfig::restart_limit`] times —
//! beyond that the shard goes *dark* (permanently unroutable; the rest
//! of the group keeps serving). A request rescued more than
//! [`GroupConfig::rescue_limit`] times (a deterministic crash loop) is
//! completed with [`StopReason::ResourceExhausted`] carrying whatever
//! was streamed. Wedged-but-alive shards keep their in-flight requests
//! — rescuing those would double-complete them when the shard resumes;
//! only their queues are drained.

use std::collections::{HashMap, HashSet, VecDeque};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::kvcache::prefix::{chain_hash, first_block_hash, ROOT_HASH};

use super::memory::{MemoryPlan, PageGeometry};
use super::metrics::{GroupMetrics, Metrics, ShardRestarts};
use super::reactor::WakeFd;
use super::request::{Completion, EngineEvent, Priority, QueuedReq, Request,
                     StopReason};
use super::DecodeEngine;

/// Router configuration for an [`EngineGroup`].
#[derive(Debug, Clone, Copy)]
pub struct GroupConfig {
    /// Number of engine shards (threads).
    pub shards: usize,
    /// A request may follow its affinity shard while that shard's
    /// load is at most this much above the fleet minimum.
    pub affinity_slack: usize,
    /// Bounded overflow queue per shard: a shard admits at most
    /// `batch + queue_depth` requests (active + queued); beyond that on
    /// every shard, `submit` rejects.
    pub queue_depth: usize,
    /// Retry hint (milliseconds) carried by [`SubmitOutcome::Deferred`]
    /// replies — how long a client should wait before resubmitting a
    /// request deferred for page-budget headroom.
    pub defer_retry_ms: u64,
    /// Track routed prefix blocks per shard and discount repeat
    /// requests' page reservations by their warm leading blocks
    /// ([`PageGeometry::prefix_discount`]). Advisory — enable together
    /// with the engines' prefix cache; an over-discount (the shard
    /// evicted the blocks since) is absorbed by engine-side eviction /
    /// preemption, exactly like any other plan optimism.
    pub prefix_routing: bool,
    /// Completion-consumer lanes: one event channel per front-end
    /// reactor (see the module docs). A lane owns the ids with
    /// `id % lanes == lane`; [`EngineGroup::into_lanes`] hands out the
    /// per-lane views. `1` (the default, with `0` treated the same)
    /// keeps the single-consumer behaviour of earlier revisions.
    pub lanes: usize,
    /// Heartbeat staleness past which the supervisor declares a shard
    /// *wedged*: circuit-broken out of routing, its queued requests
    /// moved to live shards, until the heartbeat resumes. Shard loops
    /// beat every iteration (at worst every ~20ms when idle), so
    /// values under ~100ms risk false positives on a loaded host —
    /// false positives are benign (placement only) but churn queues.
    pub wedge_timeout: Duration,
    /// Respawns the supervisor grants each shard before it goes *dark*
    /// — permanently unroutable, its requests rescued onto the rest of
    /// the group. `0` disables respawning entirely (a crash degrades
    /// to the pre-supervision fatal diagnosis once no shard is left).
    pub restart_limit: u32,
    /// Base of the exponential respawn backoff: restart `k` waits
    /// `restart_backoff_ms << min(k, 6)` milliseconds after the
    /// previous one, bounding crash-loop churn.
    pub restart_backoff_ms: u64,
    /// Times one request may be rescued off a dead shard before the
    /// supervisor stops burning restarts on it and completes it with
    /// `ResourceExhausted` carrying the tokens already streamed — the
    /// per-request crash-loop bound (a request whose very decode
    /// panics the engine would otherwise pin the whole group in a
    /// rescue/respawn cycle).
    pub rescue_limit: u32,
}

impl Default for GroupConfig {
    fn default() -> Self {
        GroupConfig { shards: 1, affinity_slack: 1, queue_depth: 32,
                      defer_retry_ms: 25, prefix_routing: false, lanes: 1,
                      wedge_timeout: Duration::from_millis(1500),
                      restart_limit: 3, restart_backoff_ms: 25,
                      rescue_limit: 8 }
    }
}

/// Result of [`EngineGroup::submit`]: routed to a shard, deferred
/// because no shard's page budget fits the request's projected peak KV
/// demand right now (count headroom exists — retry after
/// `retry_after_ms`), or rejected because every shard is at
/// `batch + queue_depth` load (or the request can never fit any shard's
/// page pool at all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    Routed(usize),
    Deferred { retry_after_ms: u64 },
    Rejected,
}

/// Internal routing verdict (see [`EngineGroup::submit`] for the
/// client-visible mapping).
enum Route {
    To(usize),
    Defer,
    Full,
}

enum ShardCmd {
    /// A request was pushed to this shard's overflow queue.
    Wake,
    /// Cancel request `id` if this shard holds it (engine or any
    /// reachable overflow queue) — broadcast to every shard, because
    /// work stealing means the submitting-time placement is not where a
    /// queued request necessarily lives.
    Cancel(u64),
    /// Finish all in-flight work, then exit and snapshot metrics.
    Shutdown,
}

enum ShardEvent {
    /// Sent once per shard after its engine constructed successfully.
    Ready { shard: usize, batch: usize, max_prompt: usize,
            geometry: PageGeometry },
    /// One generated token for an in-flight request (streamed replies).
    Token { id: u64, tok: i32, index: usize },
    /// A streaming request was preempted mid-decode (not terminal).
    Preempted { id: u64 },
    Done(Completion),
    /// Engine construction or `step` failed; the shard thread has exited.
    Fatal { shard: usize, msg: String },
}

/// What [`EngineGroup::poll_event`] yields: a token delta for an
/// in-flight request submitted with `stream = true` (non-streaming
/// requests generate no channel traffic per token), a preemption notice
/// for a streaming request (not terminal — its token stream resumes at
/// the next index after re-admission), or any request's terminal
/// completion. Per request id, every `Token` precedes the `Done` (the
/// per-shard event channel preserves emission order).
#[derive(Debug)]
pub enum GroupEvent {
    Token { id: u64, tok: i32, index: usize },
    Preempted { id: u64 },
    Done(Completion),
}

/// Everything the router needs to re-create a request lost with a dead
/// shard: the original request, the tokens already emitted toward the
/// client (`resume`, recorded at *send* time in the shard's event sink
/// — tokens buffered in the completion channel are already
/// client-visible, so a rescue must replay past them, never re-emit
/// them), and the latency bookkeeping a re-submission preserves.
struct RescueRecord {
    req: Request,
    /// Shard currently responsible for the request — follows steals,
    /// cancel-removals, and rescue transfers, so the supervisor can
    /// tell which records a dead shard held.
    shard: usize,
    arrived: Instant,
    resume: Vec<i32>,
    first_token_at: Option<Instant>,
    retries: u32,
    /// Times this request has been rescued off a dead shard — past
    /// [`GroupConfig::rescue_limit`] the supervisor answers with what
    /// it has instead of riding the crash loop.
    rescues: u32,
}

/// The state shards and the router share: overflow queues, per-shard
/// load (queued + active, the router's placement signal), and the
/// steal / queue-peak counters that feed [`GroupMetrics`].
struct ShardQueues {
    queues: Vec<Mutex<VecDeque<QueuedReq>>>,
    /// Requests accepted for shard `i` and not yet completed. Maintained
    /// by the router (push), thieves (transfer), and shards (completion),
    /// so it stays accurate across steals.
    load: Vec<AtomicUsize>,
    /// Requests shard `i` stole from other shards' queues.
    steals: Vec<AtomicU64>,
    /// Peak overflow-queue length seen at shard `i`.
    queue_peak: Vec<AtomicUsize>,
    /// Ids with a cancel pending that no engine has acknowledged yet.
    /// Closes the steal-in-progress race: a request popped from a queue
    /// *after* the cancel broadcast (by its own shard or a thief) is
    /// checked against this set at submit time, so the cancel cannot be
    /// lost in the window between queue-pop and engine-submit. Entries
    /// are removed when an engine takes ownership of the cancel, or by
    /// the router when the request's completion flows back (cancel
    /// raced a natural finish).
    cancelled: Mutex<HashSet<u64>>,
    /// Per-shard page-budget ledgers (disabled until the shard's engine
    /// reports a non-trivial [`PageGeometry`] at startup).
    plans: Vec<MemoryPlan>,
    /// Pages reserved per in-flight request id: `(owner shard, pages)`.
    /// Inserted by the router *before* the request becomes visible in a
    /// queue (so a thief's transfer always finds it), re-owned on steal
    /// / cancel-removal, and released when the completion flows back.
    reservations: Mutex<HashMap<u64, (usize, usize)>>,
    /// Cleared by shard `i`'s thread when it exits — including on panic
    /// unwind (see `AliveGuard`) — so any lane view can diagnose a dead
    /// shard without owning its `JoinHandle` (only lane 0 holds those).
    alive: Vec<AtomicBool>,
    /// Bumped by shard `i` once per `shard_main` loop iteration — the
    /// liveness signal the wedge watchdog reads. A shard parked idle
    /// still beats at least every ~20ms (its `recv_timeout` ceiling).
    heartbeats: Vec<AtomicU64>,
    /// Set by the supervisor when shard `i`'s heartbeat stalls past the
    /// wedge timeout, cleared when it resumes. Routing and probing read
    /// it lock-free; a wedged shard keeps its in-flight work (it is
    /// alive — rescuing would double-complete on resume) but receives
    /// no new placements and has its queue drained.
    wedged: Vec<AtomicBool>,
    /// Rescue records for every accepted, not-yet-completed request —
    /// inserted by the router before the request is queue-visible,
    /// token-appended by the owning shard's event sink at emit time,
    /// and removed when the completion is emitted.
    rescue: Mutex<HashMap<u64, RescueRecord>>,
}

impl ShardQueues {
    fn new(n: usize) -> ShardQueues {
        ShardQueues {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            load: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            steals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            queue_peak: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            cancelled: Mutex::new(HashSet::new()),
            plans: (0..n).map(|_| MemoryPlan::default()).collect(),
            reservations: Mutex::new(HashMap::new()),
            alive: (0..n).map(|_| AtomicBool::new(true)).collect(),
            heartbeats: (0..n).map(|_| AtomicU64::new(0)).collect(),
            wedged: (0..n).map(|_| AtomicBool::new(false)).collect(),
            rescue: Mutex::new(HashMap::new()),
        }
    }

    /// May the router place new work on shard `i`? Dead and wedged
    /// shards are circuit-broken out; both flags are plain atomics so
    /// this sits on the admission path for free.
    fn routable(&self, i: usize) -> bool {
        self.alive[i].load(Ordering::SeqCst)
            && !self.wedged[i].load(Ordering::SeqCst)
    }

    /// Record a token the owning shard has emitted toward the client
    /// for request `id`. Called from the shard's event sink at *send*
    /// time, so the record's `resume` is exactly the prefix a rescue
    /// re-submission must replay without re-emitting — recording at
    /// lane consumption instead would double-stream whatever sat
    /// unconsumed in the channel when the shard died.
    fn note_token(&self, id: u64, tok: i32, at: Instant) {
        if let Some(r) = self.rescue.lock().unwrap().get_mut(&id) {
            if r.first_token_at.is_none() {
                r.first_token_at = Some(at);
            }
            r.resume.push(tok);
        }
    }

    /// Move request `id`'s page reservation to shard `to` (steal /
    /// cancel-removal / rescue took the request there). The thief chose
    /// to take the work, so the transfer lands even over its budget
    /// (`force_reserve`); the victim's plan gets its headroom back. The
    /// rescue record's ownership moves with it, so the supervisor
    /// always knows which shard to blame for a request.
    fn transfer_reservation(&self, id: u64, to: usize) {
        {
            let mut res = self.reservations.lock().unwrap();
            if let Some(e) = res.get_mut(&id) {
                let (from, pages) = *e;
                if from != to {
                    self.plans[from].release(pages);
                    self.plans[to].force_reserve(pages);
                    e.0 = to;
                }
            }
        }
        if let Some(r) = self.rescue.lock().unwrap().get_mut(&id) {
            r.shard = to;
        }
    }

    /// Drop request `id`'s reservation (its completion was observed).
    fn release_reservation(&self, id: u64) {
        if let Some((owner, pages)) = self.reservations.lock().unwrap().remove(&id) {
            self.plans[owner].release(pages);
        }
    }

    /// Pop one queued request from the most-loaded *other* shard's
    /// overflow queue, transferring its load accounting (and page
    /// reservation) to `me`.
    fn steal_for(&self, me: usize) -> Option<QueuedReq> {
        let mut victim: Option<(usize, usize)> = None;
        for s in 0..self.queues.len() {
            if s == me {
                continue;
            }
            let qlen = self.queues[s].lock().unwrap().len();
            if qlen > 0 && victim.map(|(_, l)| qlen > l).unwrap_or(true) {
                victim = Some((s, qlen));
            }
        }
        let (v, _) = victim?;
        // Re-lock and re-check: another thief may have raced us here.
        // Sticky requests (placed on their prefix-affinity shard) are
        // skipped — stealing one would strand it on a shard without its
        // warm KV blocks, re-prefilling exactly the work the cache
        // saved. An all-sticky victim just yields nothing this round.
        let item = {
            let mut q = self.queues[v].lock().unwrap();
            let pos = q.iter().position(|it| !it.sticky)?;
            q.remove(pos)?
        };
        self.load[v].fetch_sub(1, Ordering::SeqCst);
        self.load[me].fetch_add(1, Ordering::SeqCst);
        self.steals[me].fetch_add(1, Ordering::SeqCst);
        self.transfer_reservation(item.req.id, me);
        Some(item)
    }

    /// Remove the first deadline-expired request from `me`'s own
    /// overflow queue (load accounting unchanged — the request stays
    /// this shard's). A busy shard calls this every loop iteration and
    /// routes the hit through its engine, whose step-boundary control
    /// scan completes it immediately *without* a slot — so an expired
    /// request queued behind a long decode answers at its deadline, not
    /// when a slot finally frees.
    fn pop_expired(&self, me: usize, now: Instant) -> Option<QueuedReq> {
        let mut q = self.queues[me].lock().unwrap();
        let pos = q
            .iter()
            .position(|q| q.req.deadline.map(|d| now >= d).unwrap_or(false))?;
        q.remove(pos)
    }

    /// Pop the first request in `me`'s own overflow queue whose priority
    /// is *strictly above* `floor` — the force-feed path that lets a
    /// waiting interactive request displace a batch occupant of a full
    /// engine (the engine preempts its weakest request at the next step
    /// boundary to make room). Load accounting is unchanged: the request
    /// stays this shard's.
    fn pop_higher(&self, me: usize, floor: Priority) -> Option<QueuedReq> {
        let mut q = self.queues[me].lock().unwrap();
        let pos = q.iter().position(|q| q.req.priority > floor)?;
        q.remove(pos)
    }

    /// Remove request `id` from whichever overflow queue holds it (own
    /// queue first) — the cancel analog of `steal_for`: the removal
    /// happens under the queue lock and the load accounting transfers to
    /// `me` right after, exactly like a steal, so a raced normal pop /
    /// steal and a cancel removal can never double-take the request.
    fn remove_queued(&self, me: usize, id: u64) -> Option<QueuedReq> {
        let n = self.queues.len();
        for off in 0..n {
            let s = (me + off) % n;
            let mut q = self.queues[s].lock().unwrap();
            if let Some(pos) = q.iter().position(|q| q.req.id == id) {
                let item = q.remove(pos)?;
                drop(q);
                if s != me {
                    self.load[s].fetch_sub(1, Ordering::SeqCst);
                    self.load[me].fetch_add(1, Ordering::SeqCst);
                    self.transfer_reservation(id, me);
                }
                return Some(item);
            }
        }
        None
    }
}

/// Per-shard facts reported in `Ready` and immutable afterwards, so
/// every lane view can read them without synchronization.
struct ShardInfo {
    batch: usize,
    max_prompt: usize,
    /// The shard engine's page-pool shape (reported in `Ready`); used by
    /// the router to project page demand at admission. All-zero when the
    /// engine does no page accounting.
    geometry: PageGeometry,
}

/// Wake-fd registry: one slot per lane, filled in by a front-end reactor
/// when it parks on an eventfd ([`EngineGroup::register_wake`]). Shards
/// signal the owning lane's fd after every event send, so a parked
/// reactor sees completions at syscall latency; lanes that never
/// register (trace harness, unit tests) pay nothing.
struct WakeSet {
    slots: Vec<Mutex<Option<Arc<WakeFd>>>>,
}

impl WakeSet {
    fn new(lanes: usize) -> WakeSet {
        WakeSet { slots: (0..lanes).map(|_| Mutex::new(None)).collect() }
    }

    fn set(&self, lane: usize, fd: Arc<WakeFd>) {
        *self.slots[lane].lock().unwrap() = Some(fd);
    }

    fn signal(&self, lane: usize) {
        if let Some(w) = self.slots[lane].lock().unwrap().as_ref() {
            w.signal();
        }
    }
}

/// Completion fan-out held by each shard thread: one event channel per
/// lane, addressed by id ownership (`id % lanes`). Because a lane only
/// submits its own ids, every event for a request lands on the channel
/// of the lane that submitted it, preserving the per-request
/// Token-before-Done ordering within that channel.
#[derive(Clone)]
struct EventFan {
    txs: Vec<Sender<ShardEvent>>,
    wakes: Arc<WakeSet>,
}

impl EventFan {
    fn lane_of(&self, id: u64) -> usize {
        (id % self.txs.len() as u64) as usize
    }

    fn send_to(&self, lane: usize, ev: ShardEvent) {
        let _ = self.txs[lane].send(ev);
        self.wakes.signal(lane);
    }

    fn send_for(&self, id: u64, ev: ShardEvent) {
        self.send_to(self.lane_of(id), ev);
    }

    /// `Ready` goes to lane 0: startup runs before the lanes split, and
    /// the constructor consumes lane 0's receiver.
    fn ready(&self, ev: ShardEvent) {
        self.send_to(0, ev);
    }

    /// `Fatal` is broadcast: every front-end reactor must observe a
    /// fleet failure, whichever ids it owns.
    fn fatal(&self, shard: usize, msg: &str) {
        for lane in 0..self.txs.len() {
            self.send_to(lane, ShardEvent::Fatal { shard, msg: msg.into() });
        }
    }
}

/// Clears the shard's `alive` flag when its thread exits — on clean
/// return *and* on panic unwind — so dead-shard diagnosis works from
/// any lane without the `JoinHandle`.
struct AliveGuard<'a>(&'a AtomicBool);

impl Drop for AliveGuard<'_> {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// The shard supervisor's book-keeping: one per group, shared by every
/// lane view behind `GroupCore::supervisor`. `supervise` is driven
/// opportunistically from `submit` and `poll_event` under a `try_lock`
/// — whichever lane gets there first does the round; there is no
/// dedicated watchdog thread to keep alive or shut down.
struct SupervisorState {
    /// Event fan handed to respawned shard threads — also used directly
    /// for the synthetic completion of a request whose rescue budget
    /// ran out. Retaining it means the lane channels never disconnect
    /// while the group lives; liveness diagnosis reads `alive` flags
    /// instead.
    fan: EventFan,
    /// Type-erased respawn factory: the same closure that spawned the
    /// original shard threads, so a replacement engine is configured
    /// identically to the one that died.
    spawner: Box<dyn FnMut(usize, Receiver<ShardCmd>)
                     -> std::io::Result<JoinHandle<Metrics>>
                 + Send>,
    /// Last observed heartbeat per shard, and when it last changed.
    last_beat: Vec<(u64, Instant)>,
    /// Respawns consumed per shard.
    restarts: Vec<u32>,
    /// Earliest instant shard `i` may be respawned (exponential
    /// backoff from `restart_backoff_ms`).
    next_restart: Vec<Instant>,
    /// Shard `i`'s current death has been rescued (in-flight requests
    /// re-queued, page ledger reclaimed); reset by a successful
    /// respawn. Queue drains are idempotent and run every round — this
    /// gates only the once-per-death work.
    down_handled: Vec<bool>,
    /// Restart budget exhausted: the shard stays down and unroutable
    /// for the life of the group.
    dark: Vec<bool>,
    /// Join handles of respawned incarnations, merged into the
    /// per-shard metrics at shutdown.
    extra_joins: Vec<(usize, JoinHandle<Metrics>)>,
    /// Earliest instant of the next full scan — throttles the cost of
    /// riding the submit/poll hot paths.
    next_scan: Instant,
    counters: ShardRestarts,
}

/// Router state shared by every lane view of one group. All mutation is
/// through atomics or short uncontended mutexes: `submit` can run
/// concurrently from N reactor threads.
struct GroupCore {
    shards: Vec<ShardInfo>,
    shared: Arc<ShardQueues>,
    wakes: Arc<WakeSet>,
    n_lanes: usize,
    affinity_slack: usize,
    queue_depth: usize,
    /// Retry hint carried by `Deferred` outcomes.
    defer_retry_ms: u64,
    /// Advisory routed-prefix memory per shard (empty vec when
    /// [`GroupConfig::prefix_routing`] is off).
    routed_prefixes: Mutex<Vec<PrefixTracker>>,
    /// Requests `submit` rejected because every shard was at capacity.
    rejected: AtomicU64,
    /// Requests `submit` deferred because no shard's page budget fit.
    deferred: AtomicU64,
    /// Serving-clock start: set by the first accepted `submit` on any
    /// lane, so idle time before traffic does not skew throughput.
    first_submit: Mutex<Option<Instant>>,
    /// Last completion observed by any lane — the serving-clock end when
    /// the group is already drained at `shutdown` (caller dwell between
    /// draining and shutting down must not dilute fleet throughput).
    last_done: Mutex<Option<Instant>>,
    /// The *current* control sender per shard — respawning replaces the
    /// dead incarnation's entry, so every lane (and the cancel
    /// broadcast) always reaches the live thread. Centralized here
    /// rather than cloned per lane for exactly that reason.
    cmds: Mutex<Vec<Sender<ShardCmd>>>,
    supervisor: Mutex<SupervisorState>,
    /// Set by `shutdown` before the `Shutdown` broadcast so the
    /// supervisor never mistakes a clean exit for a crash and respawns
    /// a shard that was told to stop.
    stopping: AtomicBool,
    wedge_timeout: Duration,
    restart_limit: u32,
    restart_backoff_ms: u64,
    rescue_limit: u32,
}

/// What only lane 0 holds: the shard `JoinHandle`s (joined at
/// [`EngineGroup::shutdown`]) and the not-yet-taken lane views.
struct Fleet {
    joins: Vec<JoinHandle<Metrics>>,
    spare: Vec<LaneParts>,
}

struct LaneParts {
    lane: usize,
    events: Receiver<ShardEvent>,
}

impl GroupCore {
    /// Least-loaded routable shard other than `not` — the rescue
    /// target. Falls back to `not` itself when nothing else is
    /// routable: a dead shard's own queue is where its respawned
    /// incarnation looks first, so work parked there is not lost, just
    /// waiting on the restart.
    fn rescue_target(&self, not: usize) -> usize {
        let mut best = not;
        let mut best_load = usize::MAX;
        for i in 0..self.shards.len() {
            if i == not || !self.shared.routable(i) {
                continue;
            }
            let l = self.shared.load[i].load(Ordering::SeqCst);
            if l < best_load {
                best = i;
                best_load = l;
            }
        }
        best
    }

    /// Wake shard `shard`'s current incarnation (best-effort: a send to
    /// a dead incarnation's stale channel is dropped; the respawn wakes
    /// implicitly by scanning its queue).
    fn wake_shard(&self, shard: usize) {
        let _ = self.cmds.lock().unwrap()[shard].send(ShardCmd::Wake);
    }

    /// Drain shard `d`'s overflow queue onto routable shards, one
    /// request at a time with the same load / reservation transfer
    /// discipline as a steal. Idempotent and cheap when the queue is
    /// empty, so the supervisor runs it every round for a down or
    /// wedged shard — that also catches a submit that raced the death
    /// and pushed after the rescue. Returns how many requests moved.
    fn requeue_from(&self, d: usize) -> u64 {
        let mut moved = 0u64;
        loop {
            let t = self.rescue_target(d);
            if t == d {
                break;
            }
            let item = self.shared.queues[d].lock().unwrap().pop_front();
            let Some(mut item) = item else { break };
            // The rescuing shard is not the affinity placement: unpin.
            item.sticky = false;
            let id = item.req.id;
            self.shared.load[d].fetch_sub(1, Ordering::SeqCst);
            self.shared.load[t].fetch_add(1, Ordering::SeqCst);
            self.shared.transfer_reservation(id, t);
            self.shared.queues[t].lock().unwrap().push_back(item);
            self.wake_shard(t);
            moved += 1;
        }
        moved
    }

    /// Re-create every request the dead shard `d` held *inside its
    /// engine* (records still owned by `d` after the queue drain) from
    /// the tokens it had already emitted, and queue the replays on live
    /// shards — or on `d`'s own queue for its respawn, when nothing
    /// else is routable. A record past the rescue budget is answered
    /// directly with `ResourceExhausted` and whatever was streamed:
    /// resume replay emits nothing for the carried prefix, so the
    /// client stream stays gapless either way.
    fn rescue_inflight(&self, d: usize, sup: &mut SupervisorState) {
        let ids: Vec<u64> = {
            let rec = self.shared.rescue.lock().unwrap();
            rec.iter()
                .filter(|(_, r)| r.shard == d)
                .map(|(id, _)| *id)
                .collect()
        };
        for id in ids {
            let t = self.rescue_target(d);
            let (q, over) = {
                let mut rec = self.shared.rescue.lock().unwrap();
                let Some(r) = rec.get_mut(&id) else { continue };
                r.rescues += 1;
                let over = r.rescues > self.rescue_limit;
                let q = QueuedReq::resumed(r.req.clone(), r.arrived,
                                           r.resume.clone(),
                                           r.first_token_at, r.retries);
                (q, over)
            };
            if over {
                self.shared.rescue.lock().unwrap().remove(&id);
                self.shared.release_reservation(id);
                self.shared.load[d].fetch_sub(1, Ordering::SeqCst);
                sup.counters.give_ups += 1;
                let now = Instant::now();
                let done = Completion {
                    id,
                    prompt_len: q.req.prompt.len(),
                    generated: q.resume,
                    stop: StopReason::ResourceExhausted,
                    ttft: q.first_token_at
                        .map(|t| t.saturating_duration_since(q.arrived))
                        .unwrap_or(Duration::ZERO),
                    e2e: now.saturating_duration_since(q.arrived),
                    stats: Default::default(),
                };
                sup.fan.send_for(id, ShardEvent::Done(done));
                continue;
            }
            if t != d {
                self.shared.load[d].fetch_sub(1, Ordering::SeqCst);
                self.shared.load[t].fetch_add(1, Ordering::SeqCst);
                self.shared.transfer_reservation(id, t);
            }
            self.shared.queues[t].lock().unwrap().push_back(q);
            if t != d {
                self.wake_shard(t);
            }
            sup.counters.rescued_inflight += 1;
        }
    }

    /// One supervision round: heartbeat watchdog, circuit breaking,
    /// rescue, and respawn. Rides the `submit`/`poll_event` hot paths —
    /// a `try_lock` skips the round when another lane holds it, and a
    /// scan throttle bounds the cost to one pass per few milliseconds.
    fn supervise(&self) {
        if self.stopping.load(Ordering::SeqCst) {
            return;
        }
        let Ok(mut sup) = self.supervisor.try_lock() else { return };
        let now = Instant::now();
        if now < sup.next_scan {
            return;
        }
        sup.next_scan = now + Duration::from_millis(5);
        for d in 0..self.shards.len() {
            if self.shared.alive[d].load(Ordering::SeqCst) {
                // Wedge watchdog: a stalled heartbeat circuit-breaks
                // the shard; the next beat heals it. In-flight work
                // stays put (the shard is alive — rescuing would
                // double-complete when it resumes); the queue drains
                // to shards that are actually making progress.
                let hb = self.shared.heartbeats[d].load(Ordering::Relaxed);
                if hb != sup.last_beat[d].0 {
                    sup.last_beat[d] = (hb, now);
                    if self.shared.wedged[d].load(Ordering::SeqCst) {
                        self.shared.wedged[d].store(false, Ordering::SeqCst);
                    }
                } else if now.duration_since(sup.last_beat[d].1)
                    >= self.wedge_timeout
                    && !self.shared.wedged[d].swap(true, Ordering::SeqCst)
                {
                    sup.counters.wedges += 1;
                }
                if self.shared.wedged[d].load(Ordering::SeqCst) {
                    sup.counters.rescued_queued += self.requeue_from(d);
                }
                continue;
            }
            // Dead shard: `AliveGuard` cleared the flag on its way out
            // (panic unwind included). Queue drain runs every round;
            // the in-flight rescue and ledger reclaim once per death.
            self.shared.wedged[d].store(false, Ordering::SeqCst);
            sup.counters.rescued_queued += self.requeue_from(d);
            if !sup.down_handled[d] {
                sup.down_handled[d] = true;
                self.rescue_inflight(d, &mut sup);
                sup.counters.pages_reclaimed +=
                    self.shared.plans[d].reclaim() as u64;
            }
            if sup.dark[d] {
                continue;
            }
            if sup.restarts[d] >= self.restart_limit {
                sup.dark[d] = true;
                continue;
            }
            if now < sup.next_restart[d] {
                continue;
            }
            // Respawn from the retained factory. The alive flag goes up
            // *before* the spawn so the router never sees a live thread
            // behind a down flag; a spawn failure rolls it back and
            // retires the shard.
            let (ctx, crx) = channel();
            self.shared.alive[d].store(true, Ordering::SeqCst);
            match (sup.spawner)(d, crx) {
                Ok(handle) => {
                    self.cmds.lock().unwrap()[d] = ctx;
                    sup.extra_joins.push((d, handle));
                    sup.restarts[d] += 1;
                    sup.counters.restarts += 1;
                    let wait = self.restart_backoff_ms
                        << sup.restarts[d].min(6);
                    sup.next_restart[d] = now + Duration::from_millis(wait);
                    sup.down_handled[d] = false;
                    sup.last_beat[d] =
                        (self.shared.heartbeats[d].load(Ordering::Relaxed),
                         now);
                }
                Err(_) => {
                    self.shared.alive[d].store(false, Ordering::SeqCst);
                    sup.dark[d] = true;
                }
            }
        }
    }
}

/// N decode-engine shards behind a bounded least-loaded router with
/// affinity and cross-shard work stealing. `E` itself never leaves its
/// shard thread, so the group is `Send` even for non-`Send` engines.
///
/// A group built with [`GroupConfig::lanes`] > 1 is additionally a *lane
/// view*: [`EngineGroup::into_lanes`] splits it into one `EngineGroup`
/// per lane, each owning its slice of the completion fan-in (ids with
/// `id % lanes == lane`) while routing state stays shared. Lane 0 is the
/// primary — it retains the shard threads and is the only view
/// [`EngineGroup::shutdown`] accepts.
pub struct EngineGroup<E: DecodeEngine> {
    core: Arc<GroupCore>,
    /// This lane's slice of the completion fan-in.
    events: Receiver<ShardEvent>,
    lane: usize,
    /// Requests this lane accepted and not yet collected via
    /// `poll`/`drain`.
    inflight: usize,
    /// Present on the primary (lane 0) view only.
    fleet: Option<Fleet>,
    _engine: PhantomData<fn() -> E>,
}

/// The deterministic affinity key: the rolling chain hash of the
/// prompt's first `block_tokens`-sized block — the same hash the prefix
/// caches key their first-level nodes by, so requests that could share a
/// cached first block share an affinity shard. `block_tokens == 0`
/// (engine without token paging) hashes the whole prompt, preserving
/// pure prompt affinity.
fn affinity_hash(prompt: &[i32], block_tokens: usize) -> u64 {
    first_block_hash(prompt, block_tokens)
}

/// Bounded advisory memory of prefix-block chain hashes the router has
/// sent to one shard — FIFO-evicted at `cap` (no LRU bookkeeping: a
/// false negative merely forgoes a discount, a false positive is
/// absorbed downstream like any plan optimism).
struct PrefixTracker {
    cap: usize,
    set: HashSet<u64>,
    order: VecDeque<u64>,
}

impl PrefixTracker {
    fn new(cap: usize) -> PrefixTracker {
        PrefixTracker { cap, set: HashSet::new(), order: VecDeque::new() }
    }

    fn note(&mut self, h: u64) {
        if self.set.insert(h) {
            self.order.push_back(h);
            while self.order.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.set.remove(&old);
                }
            }
        }
    }

    fn contains(&self, h: u64) -> bool {
        self.set.contains(&h)
    }
}

/// Per-shard cap on remembered routed prefix blocks.
const ROUTED_PREFIX_CAP: usize = 4096;

/// Submit a popped request, applying any cancel that raced the pop: the
/// window between a queue-pop (normal admit or steal) and the engine
/// submit is exactly where a broadcast `Cancel` could otherwise be lost
/// — the shared `cancelled` set closes it, and the engine then applies
/// the uniform cancel semantics (Finished + `StopReason::Cancelled` +
/// metrics) at its next step boundary. `streaming` is the shard-local
/// set of ids whose token events cross the completion channel.
fn submit_checked<E: DecodeEngine>(engine: &mut E, shared: &ShardQueues,
                                   streaming: &mut HashSet<u64>,
                                   q: QueuedReq) {
    let id = q.req.id;
    if q.req.stream {
        streaming.insert(id);
    }
    engine.submit_queued(q);
    if shared.cancelled.lock().unwrap().remove(&id) {
        engine.cancel(id);
    }
}

/// Apply a broadcast cancel on this shard: the engine first (it owns
/// active and engine-queued requests), then the overflow queues — a
/// still-queued request is removed and run through this shard's engine
/// as an immediately-cancelled submit (`submit_checked` sees the id
/// still marked in the cancel set and applies it), so every cancelled
/// request produces exactly one `Finished` with uniform metrics,
/// whichever stage it was caught in. Ids owned by no stage here are
/// left for the sibling broadcasts (or the submit-time check) to claim.
fn apply_cancel<E: DecodeEngine>(shard: usize, engine: &mut E,
                                 shared: &ShardQueues,
                                 streaming: &mut HashSet<u64>, id: u64) {
    if engine.cancel(id) {
        shared.cancelled.lock().unwrap().remove(&id);
        return;
    }
    if let Some(q) = shared.remove_queued(shard, id) {
        submit_checked(engine, shared, streaming, q);
    }
}

fn shard_main<E, F>(shard: usize, factory: Arc<F>, shared: Arc<ShardQueues>,
                    rx: Receiver<ShardCmd>, fan: EventFan) -> Metrics
where
    E: DecodeEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let _alive = AliveGuard(&shared.alive[shard]);
    let mut engine = match factory(shard) {
        Ok(e) => {
            fan.ready(ShardEvent::Ready {
                shard,
                batch: e.batch_size(),
                max_prompt: e.max_prompt_len(),
                geometry: e.page_geometry(),
            });
            e
        }
        Err(e) => {
            fan.fatal(shard, &format!("{e}"));
            return Metrics::new();
        }
    };
    const IDLE_WAIT_FLOOR: Duration = Duration::from_millis(1);
    const IDLE_WAIT_CEIL: Duration = Duration::from_millis(20);
    let mut shutting_down = false;
    let mut idle_wait = IDLE_WAIT_FLOOR;
    // Ids whose token events are forwarded over the completion channel
    // (requests submitted with `stream = true`); shard-thread-local, so
    // no locking on the per-token path.
    let mut streaming: HashSet<u64> = HashSet::new();
    let finish = |mut m: Metrics| {
        m.requests_stolen = shared.steals[shard].load(Ordering::SeqCst);
        m.queue_peak = shared.queue_peak[shard].load(Ordering::SeqCst) as u64;
        m
    };
    loop {
        // Liveness beat for the wedge watchdog — one bump per loop
        // iteration, so a shard stuck inside a single `step` (or a
        // fault-injected stall) reads as wedged while a merely busy
        // shard keeps beating.
        shared.heartbeats[shard].fetch_add(1, Ordering::Relaxed);
        // Admit from the own overflow queue only up to the engine's free
        // batch capacity — the remainder stays in the shared queue where
        // an idle shard can steal it.
        while engine.active() + engine.pending() < engine.batch_size() {
            let item = shared.queues[shard].lock().unwrap().pop_front();
            match item {
                Some(q) => {
                    submit_checked(&mut engine, &shared, &mut streaming, q)
                }
                None => break,
            }
        }
        // Free capacity left and nothing queued locally: steal from the
        // most-loaded shard.
        while engine.active() + engine.pending() < engine.batch_size() {
            match shared.steal_for(shard) {
                Some(q) => {
                    submit_checked(&mut engine, &shared, &mut streaming, q)
                }
                None => break,
            }
        }
        // Deadline-expired requests must not wait for a slot: pull them
        // out of the overflow queue even when the batch is full — the
        // engine's control scan completes them at the next step without
        // occupying a slot.
        {
            let now = Instant::now();
            while let Some(q) = shared.pop_expired(shard, now) {
                submit_checked(&mut engine, &shared, &mut streaming, q);
            }
        }
        // Priority fast path: a full engine never drains the overflow
        // queue on its own, so a waiting higher-priority request would
        // starve behind lower-priority occupants. Force-feed any queued
        // request strictly above the engine's current floor — the engine
        // preempts its weakest request at the next step boundary.
        while let Some(floor) = engine.min_priority() {
            match shared.pop_higher(shard, floor) {
                Some(q) => {
                    submit_checked(&mut engine, &shared, &mut streaming, q)
                }
                None => break,
            }
        }
        if engine.idle() {
            if shutting_down && shared.queues[shard].lock().unwrap().is_empty() {
                break;
            }
            // Blocking wait with exponential backoff: a Wake for this
            // shard's own queue lands instantly, while the timeout
            // bounds how stale a *steal* opportunity (queued on another
            // shard) can go unnoticed. Backoff keeps a fully idle fleet
            // near-free instead of polling at 1 kHz per shard, and any
            // activity resets it to the floor.
            match rx.recv_timeout(idle_wait) {
                Ok(ShardCmd::Wake) => idle_wait = IDLE_WAIT_FLOOR,
                Ok(ShardCmd::Cancel(id)) => {
                    idle_wait = IDLE_WAIT_FLOOR;
                    apply_cancel(shard, &mut engine, &shared, &mut streaming,
                                 id);
                }
                Err(RecvTimeoutError::Timeout) => {
                    idle_wait = (idle_wait * 2).min(IDLE_WAIT_CEIL);
                }
                Ok(ShardCmd::Shutdown) => shutting_down = true,
                Err(RecvTimeoutError::Disconnected) => break, // group dropped
            }
            continue;
        }
        idle_wait = IDLE_WAIT_FLOOR;
        // Drain control opportunistically so shutdown and cancellation
        // interleave with decode steps (Wakes are level-triggered hints;
        // the queue check above is the source of truth) — a cancel is
        // therefore applied at the latest one engine step after it
        // arrives.
        while let Ok(cmd) = rx.try_recv() {
            match cmd {
                ShardCmd::Shutdown => shutting_down = true,
                ShardCmd::Cancel(id) => {
                    apply_cancel(shard, &mut engine, &shared, &mut streaming,
                                 id);
                }
                ShardCmd::Wake => {}
            }
        }
        // One engine step, fanned out as events: tokens stream to the
        // front-end (streaming requests only — non-streaming traffic
        // pays no per-token channel cost), completions settle the load
        // accounting.
        let step = {
            let fan = &fan;
            let shared = &shared;
            let streaming = &mut streaming;
            let mut sink = |ev: EngineEvent| match ev {
                EngineEvent::Token { id, tok, index } => {
                    if streaming.contains(&id) {
                        // Record-then-send: once recorded, a rescue
                        // replays this token instead of re-emitting it,
                        // so the client stream stays gapless whether the
                        // send's buffer survived the crash or not.
                        shared.note_token(id, tok, Instant::now());
                        fan.send_for(id, ShardEvent::Token { id, tok, index });
                    }
                }
                EngineEvent::Preempted { id } => {
                    // Not terminal: the request requeued inside the
                    // engine with its partial generation. Streaming
                    // front-ends get a notice; load / reservations are
                    // untouched (the request is still this shard's).
                    if streaming.contains(&id) {
                        fan.send_for(id, ShardEvent::Preempted { id });
                    }
                }
                EngineEvent::Finished(completion) => {
                    streaming.remove(&completion.id);
                    shared.rescue.lock().unwrap().remove(&completion.id);
                    shared.release_reservation(completion.id);
                    shared.load[shard].fetch_sub(1, Ordering::SeqCst);
                    let id = completion.id;
                    fan.send_for(id, ShardEvent::Done(completion));
                }
                EngineEvent::Started { .. } => {}
            };
            engine.step_events(&mut sink)
        };
        if let Err(e) = step {
            fan.fatal(shard, &format!("{e}"));
            return finish(engine.take_metrics());
        }
    }
    finish(engine.take_metrics())
}

impl<E: DecodeEngine> EngineGroup<E> {
    /// Spawn `shards` engine threads with default routing config. The
    /// factory runs once on each shard thread (shard index as argument)
    /// and must build identically-configured engines for shard-count
    /// parity to hold.
    pub fn new<F>(shards: usize, factory: F) -> Result<EngineGroup<E>>
    where
        E: 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        Self::with_config(GroupConfig { shards, ..Default::default() }, factory)
    }

    pub fn with_config<F>(cfg: GroupConfig, factory: F) -> Result<EngineGroup<E>>
    where
        E: 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        if cfg.shards == 0 {
            bail!("engine group needs at least one shard");
        }
        let lanes = cfg.lanes.max(1);
        let factory = Arc::new(factory);
        let shared = Arc::new(ShardQueues::new(cfg.shards));
        let wakes = Arc::new(WakeSet::new(lanes));
        let mut lane_txs = Vec::with_capacity(lanes);
        let mut lane_rxs = Vec::with_capacity(lanes);
        for _ in 0..lanes {
            let (tx, rx) = channel();
            lane_txs.push(tx);
            lane_rxs.push(rx);
        }
        let fan = EventFan { txs: lane_txs, wakes: wakes.clone() };
        let mut cmds = Vec::with_capacity(cfg.shards);
        let mut joins = Vec::with_capacity(cfg.shards);
        let mut infos: Vec<ShardInfo> = (0..cfg.shards)
            .map(|_| ShardInfo { batch: 0, max_prompt: 0,
                                 geometry: PageGeometry::default() })
            .collect();
        // The one spawn path, shared by startup and the supervisor's
        // respawns, so a replacement shard is configured identically to
        // the incarnation that died.
        let mut spawner = {
            let factory = factory.clone();
            let shared = shared.clone();
            let fan = fan.clone();
            move |i: usize, crx: Receiver<ShardCmd>| {
                let f = factory.clone();
                let sq = shared.clone();
                let sfan = fan.clone();
                std::thread::Builder::new()
                    .name(format!("shard-{i}"))
                    .spawn(move || shard_main(i, f, sq, crx, sfan))
            }
        };
        for i in 0..cfg.shards {
            let (ctx, crx) = channel();
            let join = spawner(i, crx)
                .map_err(|e| anyhow!("spawn shard {i}: {e}"))?;
            cmds.push(ctx);
            joins.push(join);
        }
        // The supervisor retains a fan clone (for respawned shards and
        // synthetic rescue completions), so the lane channels stay
        // connected for the life of the group; liveness diagnosis reads
        // the `alive` flags rather than channel disconnection.
        let erx = lane_rxs.remove(0);
        // Wait for every shard's engine to come up (or fail fast) —
        // `Ready` always lands on lane 0, whose receiver this loop owns
        // until the lanes split. A slow factory (e.g. N shards
        // concurrently loading weights) is fine — we keep waiting while
        // every unready thread is still alive. A thread that *exited*
        // without sending Ready or Fatal panicked in the factory; that
        // is fatal.
        let mut ready = 0usize;
        let mut failure: Option<String> = None;
        while ready < infos.len() && failure.is_none() {
            match erx.recv_timeout(Duration::from_secs(1)) {
                Ok(ShardEvent::Ready { shard, batch, max_prompt, geometry }) => {
                    infos[shard].batch = batch;
                    infos[shard].max_prompt = max_prompt;
                    infos[shard].geometry = geometry;
                    // Arm the shard's page plan (stays disabled — admit
                    // everything — when the engine reports no geometry).
                    shared.plans[shard].set_budget(geometry.budget(cfg.queue_depth));
                    ready += 1;
                }
                Ok(ShardEvent::Fatal { shard, msg }) => {
                    failure = Some(format!("shard {shard} failed to start: {msg}"));
                }
                Ok(ShardEvent::Done(_)) => unreachable!("done before submit"),
                Ok(ShardEvent::Token { .. }) => {
                    unreachable!("token before submit")
                }
                Ok(ShardEvent::Preempted { .. }) => {
                    unreachable!("preemption before submit")
                }
                Err(RecvTimeoutError::Timeout) => {
                    if let Some((i, _)) = joins
                        .iter()
                        .enumerate()
                        .find(|(_, j)| j.is_finished())
                    {
                        failure = Some(format!(
                            "shard {i} thread exited during startup \
                             (factory panic?), {ready}/{} ready",
                            infos.len()
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    failure = Some("all shards exited at startup".into());
                }
            }
        }
        if let Some(msg) = failure {
            for tx in &cmds {
                let _ = tx.send(ShardCmd::Shutdown);
            }
            for j in joins {
                let _ = j.join();
            }
            bail!("{msg}");
        }
        let spare = lane_rxs
            .into_iter()
            .enumerate()
            .map(|(k, rx)| LaneParts { lane: k + 1, events: rx })
            .collect();
        let boot = Instant::now();
        let supervisor = SupervisorState {
            fan,
            spawner: Box::new(spawner),
            last_beat: (0..cfg.shards)
                .map(|i| (shared.heartbeats[i].load(Ordering::Relaxed), boot))
                .collect(),
            restarts: vec![0; cfg.shards],
            next_restart: vec![boot; cfg.shards],
            down_handled: vec![false; cfg.shards],
            dark: vec![false; cfg.shards],
            extra_joins: Vec::new(),
            next_scan: boot,
            counters: ShardRestarts::default(),
        };
        let core = Arc::new(GroupCore {
            shards: infos,
            shared,
            wakes,
            n_lanes: lanes,
            affinity_slack: cfg.affinity_slack,
            queue_depth: cfg.queue_depth,
            defer_retry_ms: cfg.defer_retry_ms,
            routed_prefixes: Mutex::new(if cfg.prefix_routing {
                (0..cfg.shards).map(|_| PrefixTracker::new(ROUTED_PREFIX_CAP))
                    .collect()
            } else {
                Vec::new()
            }),
            rejected: AtomicU64::new(0),
            deferred: AtomicU64::new(0),
            first_submit: Mutex::new(None),
            last_done: Mutex::new(None),
            cmds: Mutex::new(cmds),
            supervisor: Mutex::new(supervisor),
            stopping: AtomicBool::new(false),
            wedge_timeout: cfg.wedge_timeout,
            restart_limit: cfg.restart_limit,
            restart_backoff_ms: cfg.restart_backoff_ms,
            rescue_limit: cfg.rescue_limit,
        });
        Ok(EngineGroup {
            core,
            events: erx,
            lane: 0,
            inflight: 0,
            fleet: Some(Fleet { joins, spare }),
            _engine: PhantomData,
        })
    }

    /// Number of completion lanes this group was built with.
    pub fn n_lanes(&self) -> usize {
        self.core.n_lanes
    }

    /// This view's lane index (ids with `id % n_lanes == lane` belong
    /// to it).
    pub fn lane(&self) -> usize {
        self.lane
    }

    /// Split the group into its per-lane views. Element `k` owns lane
    /// `k`'s event stream and may move to its own thread (the group is
    /// `Send`); element 0 is `self`, which keeps the shard threads —
    /// call [`EngineGroup::shutdown`] on it (and only it) once every
    /// lane has finished its work. Each lane submits only ids it owns;
    /// [`EngineGroup::submit`] enforces the contract.
    pub fn into_lanes(mut self) -> Vec<EngineGroup<E>> {
        let spare = self
            .fleet
            .as_mut()
            .map(|f| std::mem::take(&mut f.spare))
            .unwrap_or_default();
        let core = self.core.clone();
        let mut out = Vec::with_capacity(spare.len() + 1);
        out.push(self);
        for p in spare {
            out.push(EngineGroup {
                core: core.clone(),
                events: p.events,
                lane: p.lane,
                inflight: 0,
                fleet: None,
                _engine: PhantomData,
            });
        }
        out
    }

    /// Register an eventfd that shards signal whenever an event lands on
    /// this lane's channel — the front-end reactor's completion wakeup
    /// (drain the fd, then drain the channel; the signal-after-send
    /// order guarantees no event is ever left behind an unsignalled fd).
    /// Re-registering replaces the previous fd.
    pub fn register_wake(&self, wake: Arc<WakeFd>) {
        self.core.wakes.set(self.lane, wake);
    }

    pub fn n_shards(&self) -> usize {
        self.core.shards.len()
    }

    /// Sum of shard batch capacities.
    pub fn total_batch(&self) -> usize {
        self.core.shards.iter().map(|s| s.batch).sum()
    }

    /// Configured per-shard overflow bound.
    pub fn queue_depth(&self) -> usize {
        self.core.queue_depth
    }

    /// Requests accepted *on this lane* and not yet collected via
    /// `poll`/`drain` (with one lane — the default — that is every
    /// outstanding request in the group).
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Per-shard load (queued + active) snapshot — router introspection
    /// for tests; changes concurrently with shard progress.
    pub fn loads(&self) -> Vec<usize> {
        self.core
            .shared
            .load
            .iter()
            .map(|l| l.load(Ordering::SeqCst))
            .collect()
    }

    /// Requests rejected by admission backpressure so far (group-wide,
    /// all lanes).
    pub fn rejected(&self) -> u64 {
        self.core.rejected.load(Ordering::Relaxed)
    }

    /// Requests deferred for page-budget headroom so far (group-wide,
    /// all lanes).
    pub fn deferred(&self) -> u64 {
        self.core.deferred.load(Ordering::Relaxed)
    }

    /// Virtual-replay admission window: keep up to one extra batch per
    /// shard queued so admission decisions are still exercised.
    pub fn admission_window(&self) -> usize {
        2 * self.total_batch().max(1)
    }

    /// Longest prompt any shard accepts (minimum across shards).
    /// Front-ends must reject longer prompts — submitting one panics
    /// the target shard's engine.
    pub fn max_prompt_len(&self) -> usize {
        self.core.shards.iter().map(|s| s.max_prompt).min().unwrap_or(0)
    }

    /// Leading full prompt blocks whose chain hashes this router already
    /// sent to `shard` — 0 when prefix routing is off or the shard's
    /// engine does no token paging. Advisory: says the shard *prefilled*
    /// those blocks at some point, not that they are still cached.
    fn warm_leading_blocks(&self, shard: usize, prompt: &[i32]) -> usize {
        let trackers = self.core.routed_prefixes.lock().unwrap();
        let Some(t) = trackers.get(shard) else { return 0 };
        let bs = self.core.shards[shard].geometry.tokens_per_page;
        if bs == 0 {
            return 0;
        }
        let mut h = ROOT_HASH;
        let mut lead = 0;
        for blk in prompt.chunks_exact(bs) {
            h = chain_hash(h, blk);
            if !t.contains(h) {
                break;
            }
            lead += 1;
        }
        lead
    }

    /// Pages to reserve for `req` on `shard`: the projected peak minus
    /// the prefix discount for its warm leading blocks — shared pages
    /// are charged once across the requests that share them.
    fn reservation_pages(&self, shard: usize, req: &Request) -> usize {
        let g = &self.core.shards[shard].geometry;
        g.project(req.prompt.len(), req.max_new).saturating_sub(
            g.prefix_discount(self.warm_leading_blocks(shard, &req.prompt)))
    }

    /// Remember the prefix-block chain of a prompt routed to `shard`.
    fn note_routed_prefix(&self, shard: usize, prompt: &[i32]) {
        let mut trackers = self.core.routed_prefixes.lock().unwrap();
        if trackers.is_empty() {
            return;
        }
        let bs = self.core.shards[shard].geometry.tokens_per_page;
        if bs == 0 {
            return;
        }
        let mut h = ROOT_HASH;
        let t = &mut trackers[shard];
        for blk in prompt.chunks_exact(bs) {
            h = chain_hash(h, blk);
            t.note(h);
        }
    }

    /// Pick the shard for a request: the prompt's affinity shard while
    /// its load is within `affinity_slack` of the minimum, below
    /// capacity, and its page plan fits the request's projected demand;
    /// else the least-loaded fitting shard with headroom. Load ties
    /// break toward this lane's shard subset (`shard % lanes == lane`) —
    /// routing locality for multi-reactor front ends — then toward the
    /// lowest index, which with one lane (every shard "local") is
    /// exactly the historical lowest-index tie-break. Prefix affinity is
    /// keyed on the prompt hash alone, so the lane preference never
    /// overrides it. `Route::Defer` when count headroom exists somewhere
    /// but no shard's page budget fits (memory is the bottleneck — retry
    /// later); `Route::Full` when every shard is at
    /// `batch + queue_depth`. One pass over the load atomics, no
    /// allocation — this sits on the admission path of every request.
    fn route(&self, req: &Request) -> Route {
        let n = self.core.shards.len();
        let load = |i: usize| self.core.shared.load[i].load(Ordering::SeqCst);
        let cap = |i: usize| self.core.shards[i].batch + self.core.queue_depth;
        let fits = |i: usize| {
            self.core.shared.plans[i].fits(self.reservation_pages(i, req))
        };
        let local = |i: usize| {
            self.core.n_lanes <= 1 || i % self.core.n_lanes == self.lane
        };
        if n == 1 {
            if !self.core.shared.routable(0) || load(0) >= cap(0) {
                return Route::Full;
            }
            return if fits(0) { Route::To(0) } else { Route::Defer };
        }
        let block = self.core.shards[0].geometry.tokens_per_page;
        let aff = (affinity_hash(&req.prompt, block) % n as u64) as usize;
        let mut min = usize::MAX;
        let mut aff_ok = false;
        let mut aff_load = usize::MAX;
        let mut count_open = false;
        let mut best = None;
        let mut best_load = usize::MAX;
        let mut best_local = false;
        for i in 0..n {
            // Dead and wedged shards are circuit-broken out entirely —
            // not "open", not affinity-eligible, not a Defer reason.
            if !self.core.shared.routable(i) {
                continue;
            }
            let l = load(i);
            if l >= cap(i) {
                continue;
            }
            count_open = true;
            min = min.min(l);
            if !fits(i) {
                continue;
            }
            if i == aff {
                aff_ok = true;
                aff_load = l;
            }
            let loc = local(i);
            if l < best_load || (l == best_load && loc && !best_local) {
                best = Some(i);
                best_load = l;
                best_local = loc;
            }
        }
        // Warm leading blocks widen the affinity window: every block
        // cached on the affinity shard is prefill work any other shard
        // would redo, so queueing a little deeper there is still the
        // cheaper placement. (Zero when prefix routing is off.)
        let warm = self.warm_leading_blocks(aff, &req.prompt);
        if aff_ok && aff_load <= min + self.core.affinity_slack + warm {
            return Route::To(aff);
        }
        match best {
            Some(i) => Route::To(i),
            None if count_open => Route::Defer,
            None => Route::Full,
        }
    }

    /// Route and dispatch a request. Latency clocks start here, so
    /// router/queue dwell is part of the reported TTFT. Returns
    /// [`SubmitOutcome::Rejected`] — without enqueueing — when every
    /// shard is at `batch + queue_depth` load (or the request can never
    /// fit any shard's page pool at all), [`SubmitOutcome::Deferred`]
    /// when count headroom exists but no shard's page budget fits right
    /// now; `Err` only on a dead shard (fleet failure, not
    /// backpressure) or on a request whose id belongs to another lane —
    /// events fan out by `id % lanes`, so submitting a foreign id here
    /// would strand its tokens on a different lane's channel.
    pub fn submit(&mut self, req: Request) -> Result<SubmitOutcome> {
        // Opportunistic supervision round: admission traffic is what
        // keeps the watchdog ticking when nobody is polling.
        self.core.supervise();
        if self.core.n_lanes > 1
            && req.id % self.core.n_lanes as u64 != self.lane as u64
        {
            bail!(
                "request id {} belongs to lane {} (this is lane {}): \
                 ids must satisfy id % lanes == lane",
                req.id,
                req.id % self.core.n_lanes as u64,
                self.lane
            );
        }
        // A request whose projected peak exceeds every shard's *whole
        // pool* can never be admitted — deferral would retry forever.
        // (Engines detect the same condition post-admission — e.g. after
        // a pool-shrink fault — and answer `ResourceExhausted`.)
        if !self.core.shards.is_empty()
            && self.core.shards.iter().all(|s| {
                s.geometry.pool_pages > 0
                    && s.geometry.project(req.prompt.len(), req.max_new)
                        > s.geometry.pool_pages
            })
        {
            self.core.rejected.fetch_add(1, Ordering::Relaxed);
            return Ok(SubmitOutcome::Rejected);
        }
        let shard = match self.route(&req) {
            Route::To(s) => s,
            Route::Defer => {
                self.core.deferred.fetch_add(1, Ordering::Relaxed);
                return Ok(SubmitOutcome::Deferred {
                    retry_after_ms: self.core.defer_retry_ms,
                });
            }
            Route::Full => {
                self.core.rejected.fetch_add(1, Ordering::Relaxed);
                return Ok(SubmitOutcome::Rejected);
            }
        };
        // Reserve the projected peak page demand — minus the prefix
        // discount for warm leading blocks — against the shard's plan.
        // `route` checked `fits` advisorily; `try_reserve` is the
        // authoritative (atomic) check, so a concurrent reservation can
        // still turn the answer into a deferral here. The discounted
        // `need` is what the reservation map records, so transfers and
        // the final release move exactly the pages that were charged.
        let need = self.reservation_pages(shard, &req);
        if !self.core.shared.plans[shard].try_reserve(need) {
            self.core.deferred.fetch_add(1, Ordering::Relaxed);
            return Ok(SubmitOutcome::Deferred {
                retry_after_ms: self.core.defer_retry_ms,
            });
        }
        // A request placed on its prefix-affinity shard is pinned there:
        // thieves must not separate it from the cached blocks it shares
        // (or, for the chain's first request, is about to publish).
        let block = self.core.shards[0].geometry.tokens_per_page;
        let sticky = !self.core.routed_prefixes.lock().unwrap().is_empty()
            && block > 0
            && req.prompt.len() >= block
            && shard
                == (affinity_hash(&req.prompt, block)
                    % self.core.shards.len() as u64) as usize;
        self.note_routed_prefix(shard, &req.prompt);
        let now = Instant::now();
        {
            let mut first = self.core.first_submit.lock().unwrap();
            if first.is_none() {
                *first = Some(now);
            }
        }
        // Record the reservation BEFORE the request becomes visible in
        // the queue, so a thief's transfer always finds it.
        let id = req.id;
        if self.core.shared.plans[shard].enabled() && need > 0 {
            self.core
                .shared
                .reservations
                .lock()
                .unwrap()
                .insert(id, (shard, need));
        }
        // Rescue record likewise precedes queue visibility, so the
        // supervisor can rebuild the request from the instant it is
        // accepted — its `resume` grows as the owning shard emits
        // tokens, and transfers keep `shard` pointing at the owner.
        self.core.shared.rescue.lock().unwrap().insert(id, RescueRecord {
            req: req.clone(),
            shard,
            arrived: now,
            resume: Vec::new(),
            first_token_at: None,
            retries: 0,
            rescues: 0,
        });
        // Count the load BEFORE the request becomes visible in the
        // queue: a fast shard (or thief) could otherwise pop + complete
        // it and fetch_sub before this add, underflowing the counter
        // and wedging admission forever.
        self.core.shared.load[shard].fetch_add(1, Ordering::SeqCst);
        let qlen = {
            let mut q = self.core.shared.queues[shard].lock().unwrap();
            q.push_back(QueuedReq { sticky, ..QueuedReq::fresh(req, now) });
            q.len()
        };
        self.core.shared.queue_peak[shard].fetch_max(qlen, Ordering::SeqCst);
        self.inflight += 1;
        // Best-effort wake: the shard may have died between `route` and
        // here — the supervisor's queue drain (run every round for a
        // down shard) then moves the request, so a lost wake is never a
        // lost request.
        let _ = self.core.cmds.lock().unwrap()[shard].send(ShardCmd::Wake);
        Ok(SubmitOutcome::Routed(shard))
    }

    /// Request cancellation of an accepted request by id. The id is
    /// marked in the shared cancel set (so a queue-pop racing this call
    /// cannot lose the cancel) and the cancel is broadcast to every
    /// shard — work stealing means a queued request may live on any
    /// shard's queue, and only the owning engine knows an active one.
    /// The request resolves through the normal completion path with
    /// [`StopReason::Cancelled`], freeing its slot and KV pages at the
    /// owning engine's next step boundary; cancelling an id that already
    /// completed is a harmless no-op. (Its cancel mark can linger until
    /// that id is seen again, so ids must not be recycled across
    /// requests — every built-in caller allocates them monotonically.)
    ///
    /// [`StopReason::Cancelled`]: super::request::StopReason::Cancelled
    pub fn cancel(&mut self, id: u64) {
        self.core.shared.cancelled.lock().unwrap().insert(id);
        for tx in self.core.cmds.lock().unwrap().iter() {
            let _ = tx.send(ShardCmd::Cancel(id));
        }
    }

    fn handle_event(&mut self, ev: ShardEvent) -> Result<Option<GroupEvent>> {
        match ev {
            ShardEvent::Token { id, tok, index } => {
                Ok(Some(GroupEvent::Token { id, tok, index }))
            }
            ShardEvent::Preempted { id } => {
                Ok(Some(GroupEvent::Preempted { id }))
            }
            ShardEvent::Done(completion) => {
                self.inflight = self.inflight.saturating_sub(1);
                *self.core.last_done.lock().unwrap() = Some(Instant::now());
                // A cancel that raced the natural finish leaves its mark
                // unclaimed; clear it here so the set cannot grow.
                self.core
                    .shared
                    .cancelled
                    .lock()
                    .unwrap()
                    .remove(&completion.id);
                Ok(Some(GroupEvent::Done(completion)))
            }
            ShardEvent::Fatal { shard, msg } => {
                bail!("shard {shard} died: {msg}")
            }
            ShardEvent::Ready { .. } => Ok(None),
        }
    }

    /// Wait up to `timeout` for one lifecycle event (a token delta or a
    /// completion). `Ok(None)` on timeout.
    pub fn poll_event(&mut self, timeout: Duration) -> Result<Option<GroupEvent>> {
        // Supervision rides the poll path too, so a fleet whose clients
        // are only *waiting* (no new submits) still detects crashes and
        // wedges, rescues, and respawns.
        self.core.supervise();
        match self.events.recv_timeout(timeout) {
            Ok(ev) => self.handle_event(ev),
            Err(RecvTimeoutError::Timeout) => {
                // An event may have landed right at the deadline — a
                // shard's Fatal message beats the generic diagnosis
                // below, so drain before scanning for dead threads.
                if let Ok(ev) = self.events.try_recv() {
                    return self.handle_event(ev);
                }
                // Dead shards are normally the supervisor's problem
                // (rescue + respawn above). What still hangs drain()
                // forever — and must surface as an error instead — is
                // the terminal state: work owed, *every* shard dead,
                // and no restart budget anywhere to bring one back.
                if self.inflight > 0
                    && (0..self.core.shards.len())
                        .all(|i| !self.core.shared.alive[i]
                            .load(Ordering::SeqCst))
                {
                    let revivable = {
                        let sup = self.core.supervisor.lock().unwrap();
                        (0..self.core.shards.len()).any(|i| {
                            !sup.dark[i]
                                && sup.restarts[i] < self.core.restart_limit
                        })
                    };
                    if !revivable {
                        if let Ok(ev) = self.events.try_recv() {
                            return self.handle_event(ev);
                        }
                        bail!(
                            "all shards dead with {} requests in flight \
                             and the restart budget exhausted",
                            self.inflight
                        );
                    }
                }
                Ok(None)
            }
            Err(RecvTimeoutError::Disconnected) => {
                bail!("all shards exited unexpectedly")
            }
        }
    }

    /// Wait up to `timeout` for one completion, discarding token deltas
    /// (the non-streaming view of the event stream). `Ok(None)` on
    /// timeout.
    pub fn poll(&mut self, timeout: Duration) -> Result<Option<Completion>> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.poll_event(left)? {
                Some(GroupEvent::Done(c)) => return Ok(Some(c)),
                // Each discarded event is channel progress, so this
                // drains rather than spins once the deadline passes.
                Some(_) => continue,
                None => return Ok(None),
            }
        }
    }

    /// Collect completions until nothing is in flight.
    pub fn drain(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while self.inflight() > 0 {
            if let Some(c) = self.poll(Duration::from_millis(5))? {
                out.push(c);
            }
        }
        Ok(out)
    }

    /// Stop all shards (they finish in-flight work first) and aggregate
    /// their metrics. Call `drain` first if completions are still owed —
    /// any left unread are dropped here. Must be called on the primary
    /// (lane 0) view, which holds the join handles; secondary lane views
    /// from [`EngineGroup::into_lanes`] are just dropped once drained.
    pub fn shutdown(self) -> Result<GroupMetrics> {
        let Some(fleet) = self.fleet else {
            bail!(
                "shutdown must be called on the primary (lane 0) view; \
                 this is lane {}",
                self.lane
            );
        };
        // Stop supervising BEFORE the Shutdown broadcast: a clean shard
        // exit clears its alive flag exactly like a crash, and the
        // supervisor must not respawn a shard that was told to stop.
        self.core.stopping.store(true, Ordering::SeqCst);
        for tx in self.core.cmds.lock().unwrap().iter() {
            let _ = tx.send(ShardCmd::Shutdown);
        }
        let first_submit = *self.core.first_submit.lock().unwrap();
        // Drained group: the clock ended at the last completion (caller
        // dwell before shutdown is not serving time). Work still in
        // flight — on this lane (`inflight`) or any other (a nonzero
        // load counter): the clock runs through the joins below, which
        // wait for the shards to finish it.
        let quiescent = self.inflight == 0
            && self
                .core
                .shared
                .load
                .iter()
                .all(|l| l.load(Ordering::SeqCst) == 0);
        let drained_end = if quiescent {
            *self.core.last_done.lock().unwrap()
        } else {
            None
        };
        // Take the supervisor's respawn handles and counters before
        // joining — never hold the supervisor mutex across a join (a
        // respawned shard's exit path may race a last supervise round).
        let (extra, supervision) = {
            let mut sup = self.core.supervisor.lock().unwrap();
            (std::mem::take(&mut sup.extra_joins), sup.counters.clone())
        };
        let mut shard_metrics = Vec::with_capacity(fleet.joins.len());
        let mut panicked = Vec::new();
        for (i, join) in fleet.joins.into_iter().enumerate() {
            match join.join() {
                Ok(m) => shard_metrics.push(m),
                Err(_) => {
                    // Keep joining: one panicked shard must not discard
                    // the healthy shards' metrics.
                    panicked.push(i);
                    shard_metrics.push(Metrics::new());
                }
            }
        }
        // Respawned incarnations fold into their shard's slot — the
        // metrics are per shard *index*, not per thread lifetime.
        for (i, join) in extra {
            match join.join() {
                Ok(m) => shard_metrics[i].merge_from(&m),
                Err(_) => panicked.push(i),
            }
        }
        panicked.sort_unstable();
        panicked.dedup();
        let wall_s = match (first_submit, drained_end) {
            (Some(t0), Some(t1)) => (t1 - t0).as_secs_f64(),
            (Some(t0), None) => t0.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        Ok(GroupMetrics {
            shards: shard_metrics,
            wall_s,
            panicked,
            rejected: self.core.rejected.load(Ordering::Relaxed),
            deferred: self.core.deferred.load(Ordering::Relaxed),
            queue_depth: self.core.queue_depth,
            reactors: Vec::new(),
            supervision,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim::{SimConfig, SimEngine};

    fn group(n: usize) -> EngineGroup<SimEngine> {
        EngineGroup::new(n, |_| Ok(SimEngine::new(SimConfig::default()))).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request::new(id, prompt, max_new)
    }

    /// Single-slot SimEngine slowed to a 2ms step, so queues stay
    /// populated long enough for admission / stealing to be observable.
    fn slow_sim() -> SimConfig {
        SimConfig { batch: 1, step_delay_ms: 2, ..Default::default() }
    }

    fn routed(o: SubmitOutcome) -> usize {
        match o {
            SubmitOutcome::Routed(s) => s,
            SubmitOutcome::Deferred { .. } => panic!("unexpected deferral"),
            SubmitOutcome::Rejected => panic!("unexpected rejection"),
        }
    }

    #[test]
    fn single_shard_runs_requests_to_completion() {
        let mut g = group(1);
        for i in 0..6u64 {
            routed(g.submit(req(i, vec![1, i as i32 + 10, 3], 8)).unwrap());
        }
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 6);
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_completed, 6);
        assert_eq!(gm.rejected, 0);
    }

    #[test]
    fn router_balances_across_shards() {
        let mut g = group(4);
        let mut seen = vec![0usize; 4];
        for i in 0..64u64 {
            let s = routed(g.submit(req(i, vec![1, i as i32, 2, 7], 6)).unwrap());
            seen[s] += 1;
        }
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 64);
        // Least-loaded + affinity must not starve any shard at 16x the
        // shard count.
        assert!(seen.iter().all(|&c| c > 0), "route counts {seen:?}");
        assert_eq!(g.inflight(), 0);
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_completed, 64);
        assert!(gm.shards.iter().all(|m| m.requests_completed > 0));
    }

    #[test]
    fn startup_failure_propagates() {
        let r: Result<EngineGroup<SimEngine>> = EngineGroup::new(2, |shard| {
            if shard == 1 {
                anyhow::bail!("boom");
            }
            Ok(SimEngine::new(SimConfig::default()))
        });
        let err = format!("{}", r.err().expect("must fail"));
        assert!(err.contains("shard 1"), "{err}");
    }

    #[test]
    fn affinity_is_deterministic_and_respected_when_unloaded() {
        let g1 = group(4);
        let prompt = vec![5, 6, 7, 8];
        // Default sim reports no token paging -> whole-prompt affinity.
        let aff = (affinity_hash(&prompt, 0) % 4) as usize;
        let mut g = g1;
        let s = routed(g.submit(req(0, prompt, 4)).unwrap());
        assert_eq!(s, aff, "idle group must honour affinity");
        g.drain().unwrap();
        g.shutdown().unwrap();
    }

    #[test]
    fn router_rejects_when_every_shard_is_at_capacity() {
        // One slow shard, batch 1, queue_depth 1 -> capacity 2. The third
        // submit must be rejected (the first can't have completed: each
        // request needs several 2ms steps).
        let cfg = GroupConfig { shards: 1, affinity_slack: 1, queue_depth: 1,
                                ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, |_| Ok(SimEngine::new(slow_sim())))
                .unwrap();
        assert_eq!(g.submit(req(0, vec![1, 2, 3], 16)).unwrap(),
                   SubmitOutcome::Routed(0));
        assert_eq!(g.submit(req(1, vec![4, 5, 6], 16)).unwrap(),
                   SubmitOutcome::Routed(0));
        assert_eq!(g.submit(req(2, vec![7, 8, 9], 16)).unwrap(),
                   SubmitOutcome::Rejected);
        assert_eq!(g.rejected(), 1);
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 2, "accepted requests still complete");
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.rejected, 1);
        assert_eq!(gm.queue_depth, 1);
        assert_eq!(gm.fleet().requests_completed, 2);
    }

    #[test]
    fn cancel_resolves_active_and_queued_requests() {
        use crate::coordinator::request::StopReason;
        // One slow single-slot shard, deep queue: req 0 becomes active,
        // reqs 1 and 2 wait in the shared overflow queue.
        let cfg = GroupConfig { shards: 1, affinity_slack: 1, queue_depth: 8,
                                ..Default::default() };
        let slow = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                               ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, move |_| Ok(SimEngine::new(slow)))
                .unwrap();
        // Request 0 streams so the test can observe it mid-decode.
        routed(g.submit(req(0, vec![1, 2], 400).with_stream()).unwrap());
        for i in 1..3u64 {
            routed(g.submit(req(i, vec![1, 2 + i as i32], 400)).unwrap());
        }
        // Wait until request 0 is demonstrably mid-decode (its token
        // events are flowing) before cancelling — no timing guesswork.
        loop {
            match g.poll_event(Duration::from_secs(5)).unwrap() {
                Some(GroupEvent::Token { id: 0, .. }) => break,
                Some(_) => {}
                None => panic!("request 0 never started decoding"),
            }
        }
        g.cancel(0); // active mid-decode
        g.cancel(2); // still queued (shard capacity is 1)
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 3, "cancelled requests still complete");
        let by_id = |id: u64| comps.iter().find(|c| c.id == id).unwrap();
        assert_eq!(by_id(0).stop, StopReason::Cancelled);
        assert!(!by_id(0).generated.is_empty(), "partial output returned");
        assert!(by_id(0).generated.len() < 400, "stopped well before max_new");
        assert_eq!(by_id(2).stop, StopReason::Cancelled);
        assert!(by_id(2).generated.is_empty(), "never admitted");
        // Request 1 unaffected: the exact deterministic generation.
        let (want, _) = SimEngine::expected_generation(&slow, &[1, 3], 400);
        assert_eq!(by_id(1).generated, want);
        let gm = g.shutdown().unwrap();
        let f = gm.fleet();
        assert_eq!(f.requests_cancelled, 2, "{}", gm.report());
        assert_eq!(f.requests_completed, 1);
        assert!(gm.report().contains("cancelled=2"), "{}", gm.report());
    }

    #[test]
    fn cancelling_unknown_or_finished_ids_is_harmless() {
        let mut g = group(1);
        routed(g.submit(req(0, vec![1, 2, 3], 6)).unwrap());
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 1);
        g.cancel(0); // already finished
        g.cancel(42); // never existed
        routed(g.submit(req(7, vec![4, 5, 6], 6)).unwrap());
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 1, "group still serves after stray cancels");
        assert_eq!(comps[0].id, 7);
        g.shutdown().unwrap();
    }

    #[test]
    fn deadline_expires_mid_decode_across_the_group() {
        use crate::coordinator::request::StopReason;
        let slow = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                               ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::new(1, move |_| Ok(SimEngine::new(slow))).unwrap();
        let r = req(0, vec![9, 8, 7], 100_000)
            .with_deadline(Instant::now() + Duration::from_millis(30));
        routed(g.submit(r).unwrap());
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].stop, StopReason::DeadlineExceeded);
        assert!(comps[0].generated.len() < 100_000, "stopped early");
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_deadline_expired, 1);
        assert!(gm.report().contains("deadline-expired=1"), "{}", gm.report());
    }

    #[test]
    fn nonstreaming_requests_send_no_token_events() {
        let mut g = group(1);
        routed(g.submit(req(0, vec![4, 4, 4], 10)).unwrap());
        loop {
            match g.poll_event(Duration::from_secs(5)).unwrap() {
                Some(GroupEvent::Token { .. }) => {
                    panic!("token event for a non-streaming request")
                }
                Some(GroupEvent::Done(_)) => break,
                None => panic!("timed out"),
            }
        }
        g.shutdown().unwrap();
    }

    #[test]
    fn queued_request_deadline_fires_while_shard_is_busy() {
        use crate::coordinator::request::StopReason;
        // One slow single-slot shard: request 0 occupies the slot for
        // ~600ms; request 1 waits in the overflow queue with a 30ms
        // deadline and must be answered at the deadline, not when the
        // slot frees.
        let slow = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                               ..Default::default() };
        let cfg = GroupConfig { shards: 1, affinity_slack: 1, queue_depth: 8,
                                ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, move |_| Ok(SimEngine::new(slow)))
                .unwrap();
        routed(g.submit(req(0, vec![1, 2], 300).with_stream()).unwrap());
        // Ensure request 0 holds the slot before queueing request 1.
        loop {
            match g.poll_event(Duration::from_secs(5)).unwrap() {
                Some(GroupEvent::Token { id: 0, .. }) => break,
                Some(_) => {}
                None => panic!("request 0 never started decoding"),
            }
        }
        let r = req(1, vec![3, 4], 300)
            .with_deadline(Instant::now() + Duration::from_millis(30));
        routed(g.submit(r).unwrap());
        // The FIRST completion must be the expired queued request —
        // request 0 keeps decoding for hundreds of ms after it.
        let first = loop {
            match g.poll_event(Duration::from_secs(5)).unwrap() {
                Some(GroupEvent::Done(c)) => break c,
                Some(_) => {}
                None => panic!("no completion"),
            }
        };
        assert_eq!(first.id, 1,
                   "expired queued request must not wait for the slot");
        assert_eq!(first.stop, StopReason::DeadlineExceeded);
        assert!(first.generated.is_empty(), "never admitted to a slot");
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].id, 0, "the busy request still completes");
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_deadline_expired, 1);
    }

    #[test]
    fn token_events_precede_their_completion() {
        use crate::coordinator::request::StopReason;
        let mut g = group(1);
        routed(g.submit(req(3, vec![5, 6, 7], 10).with_stream()).unwrap());
        let mut toks = Vec::new();
        let done = loop {
            match g.poll_event(Duration::from_secs(5)).unwrap() {
                Some(GroupEvent::Token { id, tok, index }) => {
                    assert_eq!(id, 3);
                    assert_eq!(index, toks.len(), "in-order delivery");
                    toks.push(tok);
                }
                Some(GroupEvent::Done(c)) => break c,
                None => panic!("timed out waiting for events"),
            }
        };
        assert_eq!(done.generated, toks,
                   "completion equals concatenated token events");
        let (want, stop) = SimEngine::expected_generation(
            &SimConfig::default(), &[5, 6, 7], 10);
        assert_eq!(toks, want);
        assert_eq!(done.stop, stop);
        assert_ne!(stop, StopReason::Cancelled);
        g.shutdown().unwrap();
    }

    #[test]
    fn idle_shard_steals_from_loaded_shards_queue() {
        // Two slow single-slot shards; a huge affinity slack pins every
        // request (identical prompt -> one affinity shard) onto the same
        // queue. The other shard must pull from it.
        let cfg = GroupConfig { shards: 2, affinity_slack: 1000, queue_depth: 64,
                                ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, |_| Ok(SimEngine::new(slow_sim())))
                .unwrap();
        let prompt = vec![3, 14, 15, 92];
        let aff = (affinity_hash(&prompt, 0) % 2) as usize;
        for i in 0..8u64 {
            let s = routed(g.submit(req(i, prompt.clone(), 12)).unwrap());
            assert_eq!(s, aff, "slack must pin routing to the affinity shard");
        }
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 8);
        // Stealing cannot change output: identical prompts, identical
        // generations regardless of which shard served them.
        for c in &comps {
            assert_eq!(c.generated, comps[0].generated);
        }
        let gm = g.shutdown().unwrap();
        let f = gm.fleet();
        assert_eq!(f.requests_completed, 8);
        assert!(f.requests_stolen > 0, "idle shard never stole: {}",
                gm.report());
        assert!(gm.shards.iter().all(|m| m.requests_completed > 0),
                "both shards must serve: {}", gm.report());
        assert!(f.queue_peak > 0, "queue peak untracked");
    }

    #[test]
    fn page_budget_defers_when_count_headroom_remains() {
        // Token-paged sim: pool = batch * pages_per_slot = 8 pages,
        // share = ceil(8/2) = 4, queue_depth 2 -> budget 16. Each
        // request projects (8 prompt + 55 new + 1) / 8 = 8 pages, so two
        // reservations exhaust the budget while the count cap
        // (batch + queue_depth = 4) still has room: the third submit
        // must be *deferred*, not rejected.
        let sim = SimConfig { batch: 2, pages_per_slot: 4, page_tokens: 8,
                              eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
        let cfg = GroupConfig { shards: 1, queue_depth: 2,
                                ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, move |_| Ok(SimEngine::new(sim)))
                .unwrap();
        let prompt: Vec<i32> = (1..=8).collect();
        routed(g.submit(req(0, prompt.clone(), 55)).unwrap());
        routed(g.submit(req(1, prompt.clone(), 55)).unwrap());
        assert_eq!(g.submit(req(2, prompt.clone(), 55)).unwrap(),
                   SubmitOutcome::Deferred { retry_after_ms: 25 });
        assert_eq!(g.deferred(), 1);
        assert_eq!(g.rejected(), 0, "deferral is not rejection");
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 2, "reserved requests run to completion");
        // Completions released their reservations: the same shape is
        // admissible again.
        routed(g.submit(req(3, prompt, 55)).unwrap());
        g.drain().unwrap();
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.deferred, 1);
        assert!(gm.report().contains("deferred=1"), "{}", gm.report());
    }

    #[test]
    fn reservation_follows_steal_and_cancel_removal_released_once() {
        // The reservation lifecycle driven directly (no threads, no
        // timing): router reserve -> steal -> cancel-removal -> single
        // release, with both plans' ledgers checked at every hop.
        let sq = ShardQueues::new(2);
        sq.plans[0].set_budget(10);
        sq.plans[1].set_budget(10);
        // Router path: reserve 4 pages on shard 0 and enqueue.
        assert!(sq.plans[0].try_reserve(4));
        sq.reservations.lock().unwrap().insert(7, (0, 4));
        sq.load[0].fetch_add(1, Ordering::SeqCst);
        sq.queues[0].lock().unwrap()
            .push_back(QueuedReq::fresh(req(7, vec![1, 2, 3], 4),
                                        Instant::now()));
        // Shard 1 steals: the reservation must move with the request.
        let stolen = sq.steal_for(1).expect("queued request is stealable");
        assert_eq!(stolen.req.id, 7);
        assert_eq!(sq.plans[0].planned(), 0, "victim got its headroom back");
        assert_eq!(sq.plans[1].planned(), 4, "thief now carries the pages");
        assert_eq!(sq.reservations.lock().unwrap().get(&7).unwrap().0, 1);
        assert_eq!(sq.load[1].load(Ordering::SeqCst), 1);
        // The thief requeues it (say its engine filled up), then a
        // cancel-removal on shard 0 pulls it back: same transfer
        // discipline as the steal, in the other direction.
        sq.queues[1].lock().unwrap().push_back(stolen);
        let removed = sq.remove_queued(0, 7).expect("cancel finds the request");
        assert_eq!(removed.req.id, 7);
        assert_eq!(sq.plans[1].planned(), 0);
        assert_eq!(sq.plans[0].planned(), 4);
        assert_eq!(sq.reservations.lock().unwrap().get(&7).unwrap().0, 0);
        // Completion releases the pages exactly once...
        sq.release_reservation(7);
        assert_eq!(sq.plans[0].planned(), 0);
        // ...and a duplicate release is a no-op (the entry is gone), so
        // it cannot eat a later request's reservation.
        assert!(sq.plans[0].try_reserve(2));
        sq.release_reservation(7);
        assert_eq!(sq.plans[0].planned(), 2,
                   "double release must not underflow the ledger");
    }

    #[test]
    fn prefix_affinity_routes_shared_first_blocks_together() {
        // Token-paged engines: the affinity key is the first 8-token
        // block, so prompts that diverge after block 0 still share an
        // affinity shard — where that block's KV is warm.
        let sim = SimConfig { batch: 4, pages_per_slot: 8, page_tokens: 8,
                              ..Default::default() };
        let cfg = GroupConfig { shards: 4, ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, move |_| Ok(SimEngine::new(sim)))
                .unwrap();
        let head: Vec<i32> = (1..=8).collect();
        let mut p1 = head.clone();
        p1.extend([101, 102]);
        let mut p2 = head.clone();
        p2.extend([201, 202, 203]);
        let aff = (affinity_hash(&head, 8) % 4) as usize;
        let s1 = routed(g.submit(req(0, p1, 4)).unwrap());
        let s2 = routed(g.submit(req(1, p2, 4)).unwrap());
        assert_eq!(s1, aff, "idle group must honour prefix affinity");
        assert_eq!(s2, aff, "shared first block -> same shard");
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 2);
        g.shutdown().unwrap();
    }

    #[test]
    fn prefix_routing_discounts_repeat_reservations() {
        // pool = 2*4 = 8 pages, share 4, queue_depth 2 -> budget 16.
        // Each request projects (32 + 31 + 1)/8 = 8 pages; the 32-token
        // prompt is 4 full blocks, so with prefix routing a repeat is
        // charged 8 - 4 = 4. Reservations run 8 + 4 + 4 = 16: three
        // admitted where the undiscounted plan stops at two.
        let sim = SimConfig { batch: 2, pages_per_slot: 4, page_tokens: 8,
                              eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
        let cfg = GroupConfig { shards: 1, queue_depth: 2,
                                prefix_routing: true, ..Default::default() };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(cfg, move |_| Ok(SimEngine::new(sim)))
                .unwrap();
        let prompt: Vec<i32> = (1..=32).collect();
        for i in 0..3u64 {
            routed(g.submit(req(i, prompt.clone(), 31)).unwrap());
        }
        assert_eq!(g.deferred(), 0,
                   "warm repeats must not defer on phantom page demand");
        // A fourth repeat would only cost 4 more pages, but the budget
        // is exactly full — the discounted ledger still gates.
        assert_eq!(g.submit(req(3, prompt.clone(), 31)).unwrap(),
                   SubmitOutcome::Deferred { retry_after_ms: 25 });
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 3, "admitted repeats run to completion");
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.deferred, 1);
    }

    #[test]
    fn stealing_skips_sticky_requests() {
        // Two sticky requests bracket a stealable one on shard 0: the
        // thief must take the middle (non-sticky) request, and a second
        // steal attempt — only sticky work left — must come up empty
        // even though the victim's queue is the fleet's longest.
        let sq = ShardQueues::new(2);
        let now = Instant::now();
        {
            let mut q = sq.queues[0].lock().unwrap();
            q.push_back(QueuedReq { sticky: true,
                                    ..QueuedReq::fresh(req(0, vec![1], 4), now) });
            q.push_back(QueuedReq::fresh(req(1, vec![2], 4), now));
            q.push_back(QueuedReq { sticky: true,
                                    ..QueuedReq::fresh(req(2, vec![3], 4), now) });
        }
        sq.load[0].fetch_add(3, Ordering::SeqCst);
        let stolen = sq.steal_for(1).expect("non-sticky request is stealable");
        assert_eq!(stolen.req.id, 1, "thief must skip the sticky head");
        assert!(sq.steal_for(1).is_none(), "sticky work never migrates");
        let ids: Vec<u64> = sq.queues[0].lock().unwrap()
            .iter().map(|q| q.req.id).collect();
        assert_eq!(ids, vec![0, 2], "sticky requests stay put, in order");
        // Cancel-removal still reaches sticky requests: stickiness pins
        // placement, not cancellation.
        assert!(sq.remove_queued(0, 2).is_some());
    }

    #[test]
    fn lanes_partition_events_by_id_ownership() {
        let g: EngineGroup<SimEngine> = EngineGroup::with_config(
            GroupConfig { shards: 2, lanes: 2, ..Default::default() },
            |_| Ok(SimEngine::new(SimConfig::default())),
        )
        .unwrap();
        assert_eq!(g.n_lanes(), 2);
        let mut lanes = g.into_lanes();
        assert_eq!(lanes.len(), 2);
        let mut secondary = lanes.pop().unwrap();
        let mut primary = lanes.pop().unwrap();
        assert_eq!(primary.lane(), 0);
        assert_eq!(secondary.lane(), 1);
        // Submitting a foreign id is a contract violation, not a silent
        // misroute: its events would land on the other lane's channel.
        let err = secondary.submit(req(2, vec![1, 2, 3], 4));
        assert!(err.is_err(), "lane 1 must refuse id 2");
        assert!(format!("{}", err.unwrap_err()).contains("lane"));
        for e in 0..6u64 {
            let lane = if e % 2 == 0 { &mut primary } else { &mut secondary };
            routed(lane.submit(req(e, vec![1, e as i32 + 5, 9], 6)).unwrap());
        }
        // Each lane drains exactly its own ids — nothing crosses over.
        for lane in [&mut primary, &mut secondary] {
            let comps = lane.drain().unwrap();
            assert_eq!(comps.len(), 3, "lane {} completions", lane.lane());
            for c in &comps {
                assert_eq!(c.id % 2, lane.lane() as u64,
                           "completion {} on lane {}", c.id, lane.lane());
            }
        }
        // Only the primary view may shut the fleet down.
        assert!(secondary.shutdown().is_err());
        let gm = primary.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_completed, 6);
    }

    #[test]
    fn registered_wake_fd_signals_on_events() {
        use super::super::reactor::{Interest, Reactor};
        let mut g = group(1);
        let wake = Arc::new(WakeFd::new().unwrap());
        g.register_wake(wake.clone());
        let mut r = Reactor::new().unwrap();
        r.register(wake.as_raw_fd(), 9, Interest::READ).unwrap();
        routed(g.submit(req(0, vec![1, 2, 3], 4)).unwrap());
        // The shard signals the lane's fd after each event send; a
        // reactor parked on epoll must observe it without any poll tick.
        let mut evs = Vec::new();
        let mut woke = false;
        for _ in 0..500 {
            r.wait(Duration::from_millis(10), &mut evs).unwrap();
            if evs.iter().any(|e| e.token == 9 && e.readable) {
                woke = true;
                break;
            }
        }
        assert!(woke, "completion must signal the registered eventfd");
        wake.drain();
        // The events themselves are on the channel, exactly as without a
        // wake registration.
        assert_eq!(g.drain().unwrap().len(), 1);
        g.shutdown().unwrap();
    }

    #[test]
    fn wedge_watchdog_circuit_breaks_and_recovers() {
        use crate::coordinator::sim::{Fault, FaultSchedule};
        // Shard 0 stalls 600ms inside one step (a fault-injected wedge);
        // shard 1 decodes slowly enough to stay busy while the watchdog
        // (60ms timeout) trips. Affinity slack is huge so placement is
        // pure prompt affinity — the only thing that overrides it is the
        // circuit breaker under test.
        let wedge_cfg = SimConfig {
            batch: 1,
            eos_every: 0,
            faults: FaultSchedule::none().at(2, Fault::Wedge { ms: 600 }),
            ..Default::default()
        };
        let busy_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                                   ..Default::default() };
        let gcfg = GroupConfig {
            shards: 2,
            affinity_slack: 1000,
            queue_depth: 8,
            wedge_timeout: Duration::from_millis(60),
            ..Default::default()
        };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(gcfg, move |i| {
                Ok(SimEngine::new(if i == 0 { wedge_cfg } else { busy_cfg }))
            })
            .unwrap();
        // Prompts pinned to each shard by whole-prompt affinity (the
        // default sim does no token paging).
        let mut p0 = vec![3, 1, 4];
        while (affinity_hash(&p0, 0) % 2) as usize != 0 {
            p0[2] += 1;
        }
        let mut p1 = vec![2, 7, 1];
        while (affinity_hash(&p1, 0) % 2) as usize != 1 {
            p1[2] += 1;
        }
        // Occupy shard 1's only slot (~400ms of 2ms steps) so it cannot
        // steal the queued request before the watchdog moves it.
        assert_eq!(routed(g.submit(req(0, p1.clone(), 200)).unwrap()), 1);
        // Shard 0: one in-flight request (hits the wedge mid-decode) and
        // one stuck behind it in the overflow queue.
        assert_eq!(routed(g.submit(req(1, p0.clone(), 50)).unwrap()), 0);
        assert_eq!(routed(g.submit(req(2, p0.clone(), 4)).unwrap()), 0);
        let mut comps = Vec::new();
        let watchdog = Instant::now();
        while !g.core.shared.wedged[0].load(Ordering::SeqCst) {
            assert!(watchdog.elapsed() < Duration::from_secs(20),
                    "watchdog never tripped");
            if let Some(GroupEvent::Done(c)) =
                g.poll_event(Duration::from_millis(2)).unwrap()
            {
                comps.push(c);
            }
        }
        // Circuit broken: the wedged shard's affinity traffic detours.
        assert_eq!(routed(g.submit(req(3, p0.clone(), 4)).unwrap()), 1,
                   "wedged shard must be unroutable");
        while g.core.shared.wedged[0].load(Ordering::SeqCst) {
            assert!(watchdog.elapsed() < Duration::from_secs(20),
                    "wedge never healed");
            if let Some(GroupEvent::Done(c)) =
                g.poll_event(Duration::from_millis(2)).unwrap()
            {
                comps.push(c);
            }
        }
        // Healed: affinity placement resumes on the recovered shard.
        assert_eq!(routed(g.submit(req(4, p0.clone(), 4)).unwrap()), 0,
                   "recovered shard must be routable again");
        comps.extend(g.drain().unwrap());
        assert_eq!(comps.len(), 5);
        // The wedge (and the queue rescue) must not change any output:
        // token streams are content-deterministic, placement-independent.
        for c in &comps {
            let (prompt, max_new) = match c.id {
                0 => (&p1, 200),
                1 => (&p0, 50),
                _ => (&p0, 4),
            };
            let (want, stop) =
                SimEngine::expected_generation(&wedge_cfg, prompt, max_new);
            assert_eq!(c.generated, want, "request {}", c.id);
            assert_eq!(c.stop, stop, "request {}", c.id);
        }
        let gm = g.shutdown().unwrap();
        assert!(gm.supervision.wedges >= 1, "{:?}", gm.supervision);
        assert!(gm.supervision.rescued_queued >= 1,
                "the queued request must have been moved off the wedged \
                 shard: {:?}", gm.supervision);
        assert_eq!(gm.supervision.restarts, 0,
                   "a wedge is not a crash: {:?}", gm.supervision);
        assert!(gm.panicked.is_empty());
    }

    #[test]
    fn panicked_shard_respawns_and_rescues_in_flight_requests() {
        use crate::coordinator::sim::{Fault, FaultSchedule};
        // A single shard whose engine panics at step 6 of *every*
        // incarnation: progress across the crash loop comes solely from
        // resume replay (each respawn re-prefills the tokens already
        // streamed and continues), so this pins the whole rescue path —
        // record, requeue-to-self, respawn, gapless re-emission.
        let cfg = SimConfig {
            batch: 2,
            eos_every: 0,
            faults: FaultSchedule::none().at(6, Fault::Panic),
            ..Default::default()
        };
        let gcfg = GroupConfig {
            shards: 1,
            queue_depth: 8,
            restart_limit: 64,
            restart_backoff_ms: 1,
            rescue_limit: 64,
            ..Default::default()
        };
        let mut g: EngineGroup<SimEngine> =
            EngineGroup::with_config(gcfg, move |_| Ok(SimEngine::new(cfg)))
                .unwrap();
        let prompt = vec![2, 4, 6];
        routed(g.submit(req(0, prompt.clone(), 20).with_stream()).unwrap());
        // A short non-streaming co-resident that finishes inside the
        // first incarnation's pre-panic window (non-streaming requests
        // record no resume, so they replay from the prompt — keeping
        // them short keeps the test's crash-loop bounded by request 0).
        routed(g.submit(req(1, vec![3, 5], 2)).unwrap());
        let mut toks: Vec<i32> = Vec::new();
        let mut done = Vec::new();
        let watchdog = Instant::now();
        while done.len() < 2 {
            assert!(watchdog.elapsed() < Duration::from_secs(30),
                    "rescue loop never converged; tokens={} done={}",
                    toks.len(), done.len());
            match g.poll_event(Duration::from_millis(2)).unwrap() {
                Some(GroupEvent::Token { id, tok, index }) => {
                    assert_eq!(id, 0);
                    // Gapless and duplicate-free across every crash:
                    // each delta's index is exactly the count already
                    // seen, or the rescue leaked/replayed a token.
                    assert_eq!(index, toks.len(),
                               "token stream must be gapless across respawns");
                    toks.push(tok);
                }
                Some(GroupEvent::Done(c)) => done.push(c),
                _ => {}
            }
        }
        let (want0, stop0) = SimEngine::expected_generation(&cfg, &prompt, 20);
        assert_eq!(toks, want0,
                   "streamed deltas must be bit-identical to a crash-free run");
        done.sort_by_key(|c| c.id);
        assert_eq!(done[0].generated, want0);
        assert_eq!(done[0].stop, stop0);
        let (want1, stop1) =
            SimEngine::expected_generation(&cfg, &[3, 5], 2);
        assert_eq!(done[1].generated, want1);
        assert_eq!(done[1].stop, stop1);
        assert_eq!(g.inflight(), 0);
        let gm = g.shutdown().unwrap();
        assert!(gm.supervision.restarts >= 1, "{:?}", gm.supervision);
        assert!(gm.supervision.rescued_inflight >= 1, "{:?}", gm.supervision);
        assert_eq!(gm.supervision.give_ups, 0,
                   "rescue budget must not have been exhausted: {:?}",
                   gm.supervision);
        assert_eq!(gm.panicked, vec![0]);
    }
}
