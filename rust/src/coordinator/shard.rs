//! Multi-engine sharding: request-level parallelism across N independent
//! decode engines ("shards"), each running its continuous-batching loop
//! on its own OS thread with its own KV pool and staging arena.
//!
//! Engines are deliberately **not** `Send` (the PJRT engine holds
//! `Rc<Runtime>`), so each shard thread *constructs* its own engine from
//! a `Send + Sync` factory and the engine never crosses a thread
//! boundary. The group side talks to shards over per-shard command
//! channels and a shared mpsc completion fan-in:
//!
//! ```text
//!                 submit ──► router (least-loaded + affinity)
//!                                │ ShardCmd::Submit
//!            ┌───────────┬───────┴────┬───────────┐
//!         shard 0     shard 1      shard 2     shard 3     (threads)
//!         Engine      Engine       Engine      Engine
//!            └───────────┴─────┬──────┴───────────┘
//!                              │ ShardEvent::Done(Completion)
//!                    poll / drain ──► caller
//! ```
//!
//! Routing prefers the request's *affinity shard* (a deterministic hash
//! of its prompt) while that shard's in-flight load is within
//! `affinity_slack` of the least-loaded shard, and falls back to the
//! least-loaded shard (lowest index on ties) otherwise. With
//! content-deterministic engines (greedy decoding; see `SimEngine`),
//! per-request output is independent of shard placement, so an N-shard
//! group produces byte-identical completions to a single engine —
//! `rust/tests/serving.rs` pins that property.

use std::marker::PhantomData;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::metrics::{GroupMetrics, Metrics};
use super::request::{Completion, Request};
use super::DecodeEngine;

/// Router configuration for an [`EngineGroup`].
#[derive(Debug, Clone, Copy)]
pub struct GroupConfig {
    /// Number of engine shards (threads).
    pub shards: usize,
    /// A request may follow its affinity shard while that shard's
    /// in-flight count is at most this much above the fleet minimum.
    pub affinity_slack: usize,
}

impl Default for GroupConfig {
    fn default() -> Self {
        GroupConfig { shards: 1, affinity_slack: 1 }
    }
}

enum ShardCmd {
    /// A routed request plus the instant the group observed it — the
    /// shard engine measures TTFT/e2e from that instant, so time spent
    /// in this channel counts as queueing latency.
    Submit(Request, Instant),
    /// Finish all in-flight work, then exit and snapshot metrics.
    Shutdown,
}

enum ShardEvent {
    /// Sent once per shard after its engine constructed successfully.
    Ready { shard: usize, batch: usize, max_prompt: usize },
    Done { shard: usize, completion: Completion },
    /// Engine construction or `step` failed; the shard thread has exited.
    Fatal { shard: usize, msg: String },
}

struct ShardHandle {
    tx: Sender<ShardCmd>,
    join: JoinHandle<Metrics>,
    batch: usize,
    max_prompt: usize,
}

/// N decode-engine shards behind a least-loaded router with affinity.
/// `E` itself never leaves its shard thread, so the group is `Send`
/// even for non-`Send` engines.
pub struct EngineGroup<E: DecodeEngine> {
    shards: Vec<ShardHandle>,
    events: Receiver<ShardEvent>,
    /// Requests submitted to each shard and not yet collected here.
    inflight: Vec<usize>,
    affinity_slack: usize,
    /// Serving-clock start: set by the first `submit`, so idle time
    /// between construction and traffic does not skew fleet throughput.
    first_submit: Option<Instant>,
    /// Last completion observed via `poll` — the serving-clock end when
    /// the group is already drained at `shutdown` (caller dwell between
    /// draining and shutting down must not dilute fleet throughput).
    last_done: Option<Instant>,
    _engine: PhantomData<fn() -> E>,
}

/// FNV-1a over the prompt tokens — the deterministic affinity key.
fn affinity_hash(prompt: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in prompt {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn shard_main<E, F>(shard: usize, factory: Arc<F>, rx: Receiver<ShardCmd>,
                    tx: Sender<ShardEvent>) -> Metrics
where
    E: DecodeEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let mut engine = match factory(shard) {
        Ok(e) => {
            let _ = tx.send(ShardEvent::Ready {
                shard,
                batch: e.batch_size(),
                max_prompt: e.max_prompt_len(),
            });
            e
        }
        Err(e) => {
            let _ = tx.send(ShardEvent::Fatal { shard, msg: format!("{e}") });
            return Metrics::new();
        }
    };
    let mut shutting_down = false;
    loop {
        // Block for work when idle; otherwise drain opportunistically so
        // submits interleave with decode steps (continuous batching).
        if engine.idle() {
            if shutting_down {
                break;
            }
            match rx.recv() {
                Ok(cmd) => match cmd {
                    ShardCmd::Submit(req, at) => engine.submit_at(req, at),
                    ShardCmd::Shutdown => shutting_down = true,
                },
                Err(_) => break, // group dropped
            }
        }
        loop {
            match rx.try_recv() {
                Ok(ShardCmd::Submit(req, at)) => engine.submit_at(req, at),
                Ok(ShardCmd::Shutdown) => shutting_down = true,
                Err(_) => break,
            }
        }
        if engine.idle() {
            continue;
        }
        match engine.step() {
            Ok(completions) => {
                for completion in completions {
                    let _ = tx.send(ShardEvent::Done { shard, completion });
                }
            }
            Err(e) => {
                let _ = tx.send(ShardEvent::Fatal { shard, msg: format!("{e}") });
                return engine.take_metrics();
            }
        }
    }
    engine.take_metrics()
}

impl<E: DecodeEngine> EngineGroup<E> {
    /// Spawn `shards` engine threads with default routing config. The
    /// factory runs once on each shard thread (shard index as argument)
    /// and must build identically-configured engines for shard-count
    /// parity to hold.
    pub fn new<F>(shards: usize, factory: F) -> Result<EngineGroup<E>>
    where
        E: 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        Self::with_config(GroupConfig { shards, ..Default::default() }, factory)
    }

    pub fn with_config<F>(cfg: GroupConfig, factory: F) -> Result<EngineGroup<E>>
    where
        E: 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        if cfg.shards == 0 {
            bail!("engine group needs at least one shard");
        }
        let factory = Arc::new(factory);
        let (etx, erx) = channel();
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let (ctx, crx) = channel();
            let f = factory.clone();
            let tx = etx.clone();
            let join = std::thread::Builder::new()
                .name(format!("shard-{i}"))
                .spawn(move || shard_main(i, f, crx, tx))
                .map_err(|e| anyhow!("spawn shard {i}: {e}"))?;
            shards.push(ShardHandle { tx: ctx, join, batch: 0, max_prompt: 0 });
        }
        drop(etx);
        // Wait for every shard's engine to come up (or fail fast). A
        // slow factory (e.g. N shards concurrently loading weights) is
        // fine — we keep waiting while every unready thread is still
        // alive. A thread that *exited* without sending Ready or Fatal
        // panicked in the factory; that is fatal.
        let mut ready = 0usize;
        let mut failure: Option<String> = None;
        while ready < shards.len() && failure.is_none() {
            match erx.recv_timeout(Duration::from_secs(1)) {
                Ok(ShardEvent::Ready { shard, batch, max_prompt }) => {
                    shards[shard].batch = batch;
                    shards[shard].max_prompt = max_prompt;
                    ready += 1;
                }
                Ok(ShardEvent::Fatal { shard, msg }) => {
                    failure = Some(format!("shard {shard} failed to start: {msg}"));
                }
                Ok(ShardEvent::Done { .. }) => unreachable!("done before submit"),
                Err(RecvTimeoutError::Timeout) => {
                    if let Some((i, _)) = shards
                        .iter()
                        .enumerate()
                        .find(|(_, s)| s.join.is_finished())
                    {
                        failure = Some(format!(
                            "shard {i} thread exited during startup \
                             (factory panic?), {ready}/{} ready",
                            shards.len()
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    failure = Some("all shards exited at startup".into());
                }
            }
        }
        if let Some(msg) = failure {
            for s in &shards {
                let _ = s.tx.send(ShardCmd::Shutdown);
            }
            for s in shards {
                let _ = s.join.join();
            }
            bail!("{msg}");
        }
        let n = shards.len();
        Ok(EngineGroup {
            shards,
            events: erx,
            inflight: vec![0; n],
            affinity_slack: cfg.affinity_slack,
            first_submit: None,
            last_done: None,
            _engine: PhantomData,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Sum of shard batch capacities.
    pub fn total_batch(&self) -> usize {
        self.shards.iter().map(|s| s.batch).sum()
    }

    /// Requests submitted and not yet collected via `poll`/`drain`.
    pub fn inflight(&self) -> usize {
        self.inflight.iter().sum()
    }

    /// Per-shard in-flight counts (router introspection for tests).
    pub fn inflight_per_shard(&self) -> &[usize] {
        &self.inflight
    }

    /// Virtual-replay admission window: keep up to one extra batch per
    /// shard queued so admission decisions are still exercised.
    pub fn admission_window(&self) -> usize {
        2 * self.total_batch().max(1)
    }

    /// Longest prompt any shard accepts (minimum across shards).
    /// Front-ends must reject longer prompts — submitting one panics
    /// the target shard's engine.
    pub fn max_prompt_len(&self) -> usize {
        self.shards.iter().map(|s| s.max_prompt).min().unwrap_or(0)
    }

    /// Pick the shard for a request: the prompt's affinity shard while
    /// its load is within `affinity_slack` of the minimum, else the
    /// least-loaded shard (lowest index on ties).
    fn route(&self, req: &Request) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        let aff = (affinity_hash(&req.prompt) % n as u64) as usize;
        let min = *self.inflight.iter().min().unwrap();
        if self.inflight[aff] <= min + self.affinity_slack {
            aff
        } else {
            self.inflight
                .iter()
                .enumerate()
                .min_by_key(|&(_, &c)| c)
                .map(|(i, _)| i)
                .unwrap()
        }
    }

    /// Route and dispatch a request; returns the chosen shard index.
    /// Latency clocks start here, so router/channel dwell is part of
    /// the reported TTFT.
    pub fn submit(&mut self, req: Request) -> Result<usize> {
        let now = Instant::now();
        if self.first_submit.is_none() {
            self.first_submit = Some(now);
        }
        let shard = self.route(&req);
        self.shards[shard]
            .tx
            .send(ShardCmd::Submit(req, now))
            .map_err(|_| anyhow!("shard {shard} is gone"))?;
        self.inflight[shard] += 1;
        Ok(shard)
    }

    fn handle_event(&mut self, ev: ShardEvent) -> Result<Option<Completion>> {
        match ev {
            ShardEvent::Done { shard, completion } => {
                self.inflight[shard] = self.inflight[shard].saturating_sub(1);
                self.last_done = Some(Instant::now());
                Ok(Some(completion))
            }
            ShardEvent::Fatal { shard, msg } => {
                bail!("shard {shard} died: {msg}")
            }
            ShardEvent::Ready { .. } => Ok(None),
        }
    }

    /// Wait up to `timeout` for one completion. `Ok(None)` on timeout.
    pub fn poll(&mut self, timeout: Duration) -> Result<Option<Completion>> {
        match self.events.recv_timeout(timeout) {
            Ok(ev) => self.handle_event(ev),
            Err(RecvTimeoutError::Timeout) => {
                // An event may have landed right at the deadline — a
                // shard's Fatal message beats the generic diagnosis
                // below, so drain before scanning for dead threads.
                if let Ok(ev) = self.events.try_recv() {
                    return self.handle_event(ev);
                }
                // A shard that exited while still owing completions would
                // hang drain() forever; surface it instead. (A shard
                // sends Fatal before exiting on engine *errors* — so one
                // more drain here still prefers that root cause — but a
                // *panicked* shard dies silently and lands here.)
                for (i, s) in self.shards.iter().enumerate() {
                    if self.inflight[i] > 0 && s.join.is_finished() {
                        if let Ok(ev) = self.events.try_recv() {
                            return self.handle_event(ev);
                        }
                        bail!("shard {i} exited with {} requests in flight",
                              self.inflight[i]);
                    }
                }
                Ok(None)
            }
            Err(RecvTimeoutError::Disconnected) => {
                bail!("all shards exited unexpectedly")
            }
        }
    }

    /// Collect completions until nothing is in flight.
    pub fn drain(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while self.inflight() > 0 {
            if let Some(c) = self.poll(Duration::from_millis(5))? {
                out.push(c);
            }
        }
        Ok(out)
    }

    /// Stop all shards (they finish in-flight work first) and aggregate
    /// their metrics. Call `drain` first if completions are still owed —
    /// any left unread are dropped here.
    pub fn shutdown(self) -> Result<GroupMetrics> {
        for s in &self.shards {
            let _ = s.tx.send(ShardCmd::Shutdown);
        }
        let first_submit = self.first_submit;
        // Drained group: the clock ended at the last completion (caller
        // dwell before shutdown is not serving time). Work still in
        // flight: the clock runs through the joins below, which wait
        // for the shards to finish it.
        let drained_end = if self.inflight.iter().all(|&c| c == 0) {
            self.last_done
        } else {
            None
        };
        let mut shard_metrics = Vec::with_capacity(self.shards.len());
        let mut panicked = Vec::new();
        for (i, s) in self.shards.into_iter().enumerate() {
            match s.join.join() {
                Ok(m) => shard_metrics.push(m),
                Err(_) => {
                    // Keep joining: one panicked shard must not discard
                    // the healthy shards' metrics.
                    panicked.push(i);
                    shard_metrics.push(Metrics::new());
                }
            }
        }
        let wall_s = match (first_submit, drained_end) {
            (Some(t0), Some(t1)) => (t1 - t0).as_secs_f64(),
            (Some(t0), None) => t0.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        Ok(GroupMetrics { shards: shard_metrics, wall_s, panicked })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim::{SimConfig, SimEngine};

    fn group(n: usize) -> EngineGroup<SimEngine> {
        EngineGroup::new(n, |_| Ok(SimEngine::new(SimConfig::default()))).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request { id, prompt, max_new }
    }

    #[test]
    fn single_shard_runs_requests_to_completion() {
        let mut g = group(1);
        for i in 0..6u64 {
            g.submit(req(i, vec![1, i as i32 + 10, 3], 8)).unwrap();
        }
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 6);
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_completed, 6);
    }

    #[test]
    fn router_balances_across_shards() {
        let mut g = group(4);
        let mut seen = vec![0usize; 4];
        for i in 0..64u64 {
            let s = g.submit(req(i, vec![1, i as i32, 2, 7], 6)).unwrap();
            seen[s] += 1;
        }
        let comps = g.drain().unwrap();
        assert_eq!(comps.len(), 64);
        // Least-loaded + affinity must not starve any shard at 16x the
        // shard count.
        assert!(seen.iter().all(|&c| c > 0), "route counts {seen:?}");
        assert_eq!(g.inflight(), 0);
        let gm = g.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_completed, 64);
        assert!(gm.shards.iter().all(|m| m.requests_completed > 0));
    }

    #[test]
    fn startup_failure_propagates() {
        let r: Result<EngineGroup<SimEngine>> = EngineGroup::new(2, |shard| {
            if shard == 1 {
                anyhow::bail!("boom");
            }
            Ok(SimEngine::new(SimConfig::default()))
        });
        let err = format!("{}", r.err().expect("must fail"));
        assert!(err.contains("shard 1"), "{err}");
    }

    #[test]
    fn affinity_is_deterministic_and_respected_when_unloaded() {
        let g1 = group(4);
        let prompt = vec![5, 6, 7, 8];
        let aff = (affinity_hash(&prompt) % 4) as usize;
        let mut g = g1;
        let s = g.submit(req(0, prompt, 4)).unwrap();
        assert_eq!(s, aff, "idle group must honour affinity");
        g.drain().unwrap();
        g.shutdown().unwrap();
    }
}
