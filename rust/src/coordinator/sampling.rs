//! Token sampling from LM-head logits.

use crate::util::rng::Rng;

/// Greedy argmax (ties -> lowest id, deterministic).
pub fn greedy(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Temperature sampling (temperature <= 0 falls back to greedy).
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return greedy(logits);
    }
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let probs: Vec<f64> = logits
        .iter()
        .map(|&v| (((v - m) / temperature) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.f64() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(greedy(&[5.0, 5.0]), 0, "tie -> lowest id");
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(0);
        assert_eq!(sample(&[0.0, 9.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sampling_respects_distribution() {
        // Token 2 has overwhelming mass at low temperature.
        let mut rng = Rng::new(1);
        let logits = [0.0, 0.0, 10.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..200 {
            counts[sample(&logits, 0.5, &mut rng) as usize] += 1;
        }
        assert!(counts[2] > 195, "{counts:?}");
    }

    #[test]
    fn high_temperature_spreads() {
        let mut rng = Rng::new(2);
        let logits = [1.0, 1.0, 1.0, 1.0];
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[sample(&logits, 1.0, &mut rng) as usize] += 1;
        }
        for c in counts {
            assert!(c > 800, "{counts:?}");
        }
    }
}
