//! L3 coordinator: the serving engine (continuous batching over the
//! AOT-compiled decode executables), sampling, scheduling, metrics, and
//! the TCP server.

pub mod engine;
pub mod metrics;
pub mod request;
pub mod sampling;
pub mod scheduler;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use request::{Completion, Request};
