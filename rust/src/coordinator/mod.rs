//! L3 coordinator: the serving engine (continuous batching over the
//! AOT-compiled decode executables), sampling, scheduling, sharding, and
//! the TCP server.
//!
//! The PJRT-backed [`Engine`] is gated behind the `pjrt` feature; the
//! serving layer above it — [`EngineGroup`] sharding, the trace-driven
//! scheduler, the JSON-lines TCP server, the staging arena, sampling,
//! request types, and metrics — is pure host code, generic over the
//! [`DecodeEngine`] trait, and always available. [`SimEngine`] is the
//! deterministic host-only reference engine the end-to-end serving tests
//! drive through the exact same scheduler/router/server code paths the
//! PJRT engine uses in production.

pub mod arena;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod gather;
pub mod memory;
pub mod metrics;
pub mod reactor;
pub mod request;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod sim;

pub use arena::StagingArena;
#[cfg(feature = "pjrt")]
pub use engine::{Engine, EngineConfig};
pub use memory::{MemoryPlan, PageGeometry};
pub use metrics::{GroupMetrics, Metrics, ReactorStats, ShardRestarts};
pub use request::{Completion, EngineEvent, Priority, QueuedReq, Request, StopReason};
pub use server::ServeConfig;
pub use shard::{EngineGroup, GroupConfig, GroupEvent, SubmitOutcome};
pub use sim::{Fault, FaultSchedule, SimConfig, SimEngine};

/// The contract between a decode engine (one continuous-batching loop
/// over one device) and the serving layer above it (shard router, trace
/// scheduler, TCP server). The PJRT [`Engine`] and the host-only
/// [`SimEngine`] both implement it, so every serving code path is
/// testable under the default feature set.
pub trait DecodeEngine {
    /// Enqueue a request (admitted into a batch slot on a later `step`).
    fn submit(&mut self, req: Request) {
        self.submit_at(req, std::time::Instant::now());
    }

    /// Enqueue a request whose arrival was observed at `arrived` —
    /// TTFT/e2e are measured from that instant. The shard router uses
    /// this so time spent in the router-to-shard channel counts toward
    /// latency, exactly as client-visible queueing should.
    fn submit_at(&mut self, req: Request, arrived: std::time::Instant);

    /// Enqueue a queued-request record, preserving any resume state it
    /// carries (partial generation from a preemption, original arrival,
    /// first-token instant, retry count). The default drops resume state
    /// and submits fresh — correct only for engines that never preempt;
    /// preempting engines override it.
    fn submit_queued(&mut self, q: QueuedReq) {
        self.submit_at(q.req, q.arrived);
    }

    /// One engine iteration: admit+prefill if possible, else decode one
    /// token for the running batch. Returns finished completions.
    fn step(&mut self) -> anyhow::Result<Vec<Completion>>;

    /// One engine iteration as an **event stream**: every lifecycle event
    /// ([`EngineEvent::Started`] / [`Token`](EngineEvent::Token) /
    /// [`Finished`](EngineEvent::Finished)) is pushed into `sink` in
    /// order. The default implementation wraps [`step`](Self::step) and
    /// emits only `Finished` events, so pre-existing engine impls keep
    /// compiling (and keep working behind non-streaming callers); the
    /// PJRT `Engine` and [`SimEngine`] override it to emit token-level
    /// events natively.
    fn step_events(&mut self,
                   sink: &mut dyn FnMut(EngineEvent)) -> anyhow::Result<()> {
        for c in self.step()? {
            sink(EngineEvent::Finished(c));
        }
        Ok(())
    }

    /// Flag request `id` for cancellation. Returns `true` when this
    /// engine owns the request (queued or mid-decode): it will stop at
    /// the next step boundary, release its slot and KV pages, and emit
    /// `Finished` with [`StopReason::Cancelled`] carrying the tokens
    /// generated so far. Returns `false` when the id is unknown here
    /// (already completed, or owned by another shard). The default —
    /// for external impls that predate cancellation — refuses.
    fn cancel(&mut self, _id: u64) -> bool {
        false
    }

    /// Requests queued but not yet admitted.
    fn pending(&self) -> usize;

    /// Requests currently occupying batch slots.
    fn active(&self) -> usize;

    /// Concurrent batch capacity (slots).
    fn batch_size(&self) -> usize;

    /// Longest prompt `submit` accepts (the context window minus room
    /// for generation bookkeeping). Front-ends must reject longer
    /// prompts instead of submitting them.
    fn max_prompt_len(&self) -> usize;

    fn idle(&self) -> bool {
        self.pending() == 0 && self.active() == 0
    }

    /// The engine's KV page pool shape, used by the shard router to
    /// project a request's peak page demand at admission. The default
    /// (all-zero geometry) disables page planning for this engine.
    fn page_geometry(&self) -> PageGeometry {
        PageGeometry::default()
    }

    /// The lowest priority among requests this engine currently holds
    /// (active and not yet stopping, or waiting in its internal queue).
    /// `None` when the engine holds nothing. The shard loop uses this to
    /// force-feed a strictly-higher-priority overflow request into a
    /// full engine so pressure preemption can evict a weaker occupant in
    /// its favour.
    fn min_priority(&self) -> Option<Priority> {
        None
    }

    /// Move the engine's metrics out (shard shutdown snapshot).
    fn take_metrics(&mut self) -> Metrics;
}
