//! L3 coordinator: the serving engine (continuous batching over the
//! AOT-compiled decode executables), sampling, scheduling, metrics, and
//! the TCP server.
//!
//! The engine, scheduler, and server need the PJRT runtime and are gated
//! behind the `pjrt` feature; the staging arena, sampling, request types,
//! and metrics are pure host code and always available (the decode
//! hot-path bench exercises them offline).

pub mod arena;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod metrics;
pub mod request;
pub mod sampling;
#[cfg(feature = "pjrt")]
pub mod scheduler;
#[cfg(feature = "pjrt")]
pub mod server;

pub use arena::StagingArena;
#[cfg(feature = "pjrt")]
pub use engine::{Engine, EngineConfig};
pub use request::{Completion, Request};
