//! Streaming-request end-to-end smoke bench: drives one
//! `{"stream": true}` request through the real reactor front-end +
//! shard + `SimEngine` stack over a real socket, asserting the event
//! path works (≥1 delta frame before the terminal reply, concatenated
//! deltas byte-identical to `generated`, which equals the sim
//! reference), and reports time-to-first-delta and end-to-end time.
//!
//! Runs identically under `scripts/bench.sh --smoke` — it is cheap by
//! construction — so the streaming event path can never rot uncompiled
//! or unexercised in CI.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use seerattn::coordinator::{server, EngineGroup, ServeConfig, SimConfig,
                            SimEngine};
use seerattn::util::json::Json;

fn main() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, |_| Ok(SimEngine::new(SimConfig::default())))
            .unwrap();
    let cfg = ServeConfig { limit: Some(1), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let prompt = vec![1, 17, 29, 3];
    let max_new = 32usize;
    let mut conn = TcpStream::connect(addr).unwrap();
    let t0 = Instant::now();
    writeln!(conn,
             "{{\"id\": 1, \"prompt\": [1, 17, 29, 3], \"max_new\": {max_new}, \
              \"stream\": true}}")
        .unwrap();
    conn.flush().unwrap();

    let mut reader = BufReader::new(conn);
    let mut deltas: Vec<i32> = Vec::new();
    let mut first_delta = None;
    let terminal = loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0,
                "EOF before terminal reply");
        let j = Json::parse(&line)
            .unwrap_or_else(|_| panic!("bad frame {line:?}"));
        assert!(j.get("error").is_err(), "unexpected error {line:?}");
        if j.opt("stop").is_some() {
            break j;
        }
        if first_delta.is_none() {
            first_delta = Some(t0.elapsed());
        }
        for t in j.get("delta").unwrap().as_arr().unwrap() {
            deltas.push(t.as_i64().unwrap() as i32);
        }
    };
    let e2e = t0.elapsed();
    srv.join().unwrap();

    let generated: Vec<i32> = terminal
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_i64().unwrap() as i32)
        .collect();
    let (want, _) =
        SimEngine::expected_generation(&SimConfig::default(), &prompt, max_new);
    assert!(!deltas.is_empty(), "no delta frame arrived before Finished");
    assert_eq!(deltas, generated, "concatenated deltas != final generated");
    assert_eq!(generated, want, "generation != sim reference");
    println!(
        "serving_stream: {} delta tokens, time-to-first-delta {:.3} ms, \
         e2e {:.3} ms",
        deltas.len(),
        first_delta.unwrap().as_secs_f64() * 1e3,
        e2e.as_secs_f64() * 1e3
    );
}
