//! Streaming-request end-to-end smoke bench: drives `{"stream": true}`
//! requests through the real reactor front-end + shard + `SimEngine`
//! stack over real sockets.
//!
//! Two sections:
//!
//!  1. **Single-stream parity** (the original smoke): one streaming
//!     request, asserting the event path works (≥1 delta frame before
//!     the terminal reply, concatenated deltas byte-identical to
//!     `generated`, which equals the sim reference), reporting
//!     time-to-first-delta and end-to-end time.
//!  2. **Reactor scaling**: the same pipelined streaming workload served
//!     at `--reactors` 1, 2, and 4 (multi-lane group + accept-handoff
//!     fan-out over a pre-bound listener), measuring connection-setup
//!     time, idle time-to-first-delta (every reactor parked in
//!     `epoll_wait` with *no poll tick* — the first delta must arrive at
//!     eventfd/syscall latency, not at a tick boundary), and aggregate
//!     streaming token throughput. Every reply is still asserted
//!     byte-identical to the sim reference, so the scaling section is a
//!     parity test that happens to be timed.
//!
//! Runs identically under `scripts/bench.sh --smoke` — it is cheap by
//! construction — so the streaming and multi-reactor event paths can
//! never rot uncompiled or unexercised in CI. Outside smoke mode the
//! scaling numbers are merged into `BENCH_decode.json` under the
//! `"serving"` key (read-modify-write: the decode bench owns the rest of
//! the file and runs first in `bench.sh`).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use seerattn::coordinator::{server, EngineGroup, GroupConfig, ServeConfig,
                            SimConfig, SimEngine};
use seerattn::util::json::Json;

fn prompt_for(id: usize) -> Vec<i32> {
    vec![1, 17, 29, 3 + (id % 7) as i32]
}

fn stream_request_line(id: usize, prompt: &[i32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"id\": {id}, \"prompt\": [{}], \"max_new\": {max_new}, \
             \"stream\": true}}",
            toks.join(", "))
}

/// Single streaming request through a 1-shard group: asserts the delta
/// path and returns (time-to-first-delta ms, end-to-end ms).
fn single_stream_parity() -> (f64, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, |_| Ok(SimEngine::new(SimConfig::default())))
            .unwrap();
    let cfg = ServeConfig { limit: Some(1), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let prompt = vec![1, 17, 29, 3];
    let max_new = 32usize;
    let mut conn = TcpStream::connect(addr).unwrap();
    let t0 = Instant::now();
    writeln!(conn,
             "{{\"id\": 1, \"prompt\": [1, 17, 29, 3], \"max_new\": {max_new}, \
              \"stream\": true}}")
        .unwrap();
    conn.flush().unwrap();

    let mut reader = BufReader::new(conn);
    let mut deltas: Vec<i32> = Vec::new();
    let mut first_delta = None;
    let terminal = loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0,
                "EOF before terminal reply");
        let j = Json::parse(&line)
            .unwrap_or_else(|_| panic!("bad frame {line:?}"));
        assert!(j.get("error").is_err(), "unexpected error {line:?}");
        if j.opt("stop").is_some() {
            break j;
        }
        if first_delta.is_none() {
            first_delta = Some(t0.elapsed());
        }
        for t in j.get("delta").unwrap().as_arr().unwrap() {
            deltas.push(t.as_i64().unwrap() as i32);
        }
    };
    let e2e = t0.elapsed();
    srv.join().unwrap();

    let generated: Vec<i32> = terminal
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_i64().unwrap() as i32)
        .collect();
    let (want, _) =
        SimEngine::expected_generation(&SimConfig::default(), &prompt, max_new);
    assert!(!deltas.is_empty(), "no delta frame arrived before Finished");
    assert_eq!(deltas, generated, "concatenated deltas != final generated");
    assert_eq!(generated, want, "generation != sim reference");
    let ttfd_ms = first_delta.unwrap().as_secs_f64() * 1e3;
    let e2e_ms = e2e.as_secs_f64() * 1e3;
    println!(
        "serving_stream: {} delta tokens, time-to-first-delta {ttfd_ms:.3} ms, \
         e2e {e2e_ms:.3} ms",
        deltas.len(),
    );
    (ttfd_ms, e2e_ms)
}

struct ScalingRun {
    reactors: usize,
    conn_setup_ms: f64,
    idle_first_delta_ms: f64,
    tokens_per_s: f64,
}

/// One reactor-scaling leg: a 4-shard group with `reactors` lanes served
/// by `reactors` reactor threads (pre-bound listener, so >1 reactor uses
/// the accept-handoff fan-out — the path that works on every kernel),
/// driven by `n_conns` pipelined streaming connections.
fn scaling_run(reactors: usize, n_conns: usize, reqs: usize,
               max_new: usize) -> ScalingRun {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group: EngineGroup<SimEngine> = EngineGroup::with_config(
        GroupConfig { shards: 4, lanes: reactors, ..Default::default() },
        |_| Ok(SimEngine::new(SimConfig::default())),
    )
    .unwrap();
    let cfg = ServeConfig { limit: Some(reqs), reactors,
                            ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // Connection setup: each connect exercises accept + (for reactors
    // beyond the first) the cross-reactor handoff + wake + epoll
    // registration on the adopting reactor.
    let t = Instant::now();
    let conns: Vec<TcpStream> =
        (0..n_conns).map(|_| TcpStream::connect(addr).unwrap()).collect();
    let conn_setup_ms = t.elapsed().as_secs_f64() * 1e3;
    let mut writers: Vec<TcpStream> =
        conns.iter().map(|c| c.try_clone().unwrap()).collect();
    let mut readers: Vec<BufReader<TcpStream>> =
        conns.into_iter().map(BufReader::new).collect();

    // Idle wake latency: let every reactor park in epoll_wait (nothing
    // due, no tick), then send one request and time the first delta.
    std::thread::sleep(Duration::from_millis(50));
    let t = Instant::now();
    writeln!(writers[0], "{}",
             stream_request_line(0, &prompt_for(0), max_new))
        .unwrap();
    writers[0].flush().unwrap();
    let idle_first_delta_ms = loop {
        let mut line = String::new();
        assert!(readers[0].read_line(&mut line).unwrap() > 0,
                "EOF before first delta");
        let j = Json::parse(&line)
            .unwrap_or_else(|_| panic!("bad frame {line:?}"));
        assert!(j.get("error").is_err(), "unexpected error {line:?}");
        if j.opt("delta").is_some() {
            break t.elapsed().as_secs_f64() * 1e3;
        }
        assert!(j.opt("stop").is_none(), "terminal before any delta");
    };

    // Aggregate streaming throughput: the remaining requests fan
    // round-robin over every connection, all streaming, all in flight
    // together.
    let t = Instant::now();
    for id in 1..reqs {
        let c = id % n_conns;
        writeln!(writers[c], "{}",
                 stream_request_line(id, &prompt_for(id), max_new))
            .unwrap();
    }
    for w in &mut writers {
        w.flush().unwrap();
    }
    // Drain every connection: frames for the requests pipelined on one
    // connection interleave, so accumulate deltas per id and stop after
    // that connection's expected terminal count.
    let mut deltas: BTreeMap<usize, Vec<i32>> = BTreeMap::new();
    let mut generated: BTreeMap<usize, Vec<i32>> = BTreeMap::new();
    for (c, reader) in readers.iter_mut().enumerate() {
        // id 0 went to conn 0 in the idle phase; 0 % n_conns == 0, so
        // one modular filter covers both phases.
        let expected_terminals = (0..reqs).filter(|&id| id % n_conns == c).count();
        let mut terminals = 0usize;
        while terminals < expected_terminals {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0,
                    "conn {c}: EOF with {terminals}/{expected_terminals} \
                     terminals");
            let j = Json::parse(&line)
                .unwrap_or_else(|_| panic!("bad frame {line:?}"));
            assert!(j.get("error").is_err(), "unexpected error {line:?}");
            let id = j.get("id").unwrap().as_i64().unwrap() as usize;
            if j.opt("stop").is_some() {
                terminals += 1;
                let g: Vec<i32> = j
                    .get("generated").unwrap().as_arr().unwrap()
                    .iter().map(|t| t.as_i64().unwrap() as i32).collect();
                generated.insert(id, g);
            } else {
                let d = deltas.entry(id).or_default();
                for t in j.get("delta").unwrap().as_arr().unwrap() {
                    d.push(t.as_i64().unwrap() as i32);
                }
            }
        }
    }
    let wall = t.elapsed();
    srv.join().unwrap();

    // Parity: every reply equals the sim reference, and every stream's
    // concatenated deltas equal its terminal `generated`.
    assert_eq!(generated.len(), reqs, "reactors={reactors}: lost a reply");
    let mut tokens = 0usize;
    for (id, g) in &generated {
        let (want, _) = SimEngine::expected_generation(
            &SimConfig::default(), &prompt_for(*id), max_new);
        assert_eq!(g, &want, "reactors={reactors} id {id}: generation \
                              != sim reference");
        assert_eq!(deltas.get(id).unwrap(), g,
                   "reactors={reactors} id {id}: deltas != generated");
        if *id != 0 {
            tokens += g.len(); // id 0 decoded before the timed window
        }
    }
    let tokens_per_s = tokens as f64 / wall.as_secs_f64();
    println!(
        "serving_stream: reactors={reactors} conn_setup {conn_setup_ms:.3} ms \
         ({n_conns} conns), idle-first-delta {idle_first_delta_ms:.3} ms, \
         {tokens} tokens in {:.3} ms => {tokens_per_s:.0} tok/s",
        wall.as_secs_f64() * 1e3,
    );
    ScalingRun { reactors, conn_setup_ms, idle_first_delta_ms, tokens_per_s }
}

fn main() {
    let smoke = std::env::var("SEERATTN_BENCH_SMOKE").as_deref() == Ok("1");
    let (ttfd_ms, e2e_ms) = single_stream_parity();

    // Reactor scaling: same workload at 1, 2, and 4 reactors. Sizes are
    // identical in smoke mode — the section is cheap — only the JSON
    // rewrite is gated.
    let runs: Vec<ScalingRun> = [1usize, 2, 4]
        .iter()
        .map(|&r| scaling_run(r, 6, 18, 32))
        .collect();

    if smoke {
        println!("smoke mode: all asserts green, BENCH_decode.json untouched");
        return;
    }
    // Merge the serving section into BENCH_decode.json (owned and
    // rewritten wholesale by decode_hot_path, which bench.sh runs first).
    let root = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| std::path::PathBuf::from(d).parent().unwrap().to_path_buf())
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    let path = root.join("BENCH_decode.json");
    let mut parsed =
        Json::parse_file(&path).unwrap_or(Json::Obj(BTreeMap::new()));
    let scaling = Json::Arr(
        runs.iter()
            .map(|r| Json::obj(vec![
                ("reactors", Json::Num(r.reactors as f64)),
                ("conn_setup_ms", Json::Num(r.conn_setup_ms)),
                ("idle_first_delta_ms", Json::Num(r.idle_first_delta_ms)),
                ("stream_tokens_per_s", Json::Num(r.tokens_per_s)),
            ]))
            .collect(),
    );
    let serving = Json::obj(vec![
        ("stream_ttfd_ms", Json::Num(ttfd_ms)),
        ("stream_e2e_ms", Json::Num(e2e_ms)),
        ("reactor_scaling", scaling),
    ]);
    if let Json::Obj(ref mut m) = parsed {
        m.insert("serving".to_string(), serving);
    }
    std::fs::write(&path, parsed.to_string())
        .expect("write BENCH_decode.json");
    println!("merged serving section into {}", path.display());
}
