//! Fig 6 benchmark: block-sparse flash-decoding kernel vs dense baseline
//! across seqlen x batch x sparsity (`cargo bench --bench
//! fig6_kernel_speedup`). Also reachable as `seerattn repro fig6`.

use seerattn::harness::{self, experiments};

fn main() {
    if !harness::artifacts_available() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    }
    let budget: f64 = std::env::var("BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    experiments::fig6(&harness::artifacts_dir(), budget).unwrap();
}
