//! AttnGate host-side overhead benchmark: the paper's claim is that the
//! gate is lightweight next to attention. Measures the per-token cost of
//! (a) a K-compression-cache update, (b) gate scoring + top-k selection,
//! (c) Quest min/max maintenance + scoring, against (d) the dense-cache
//! gather that a dense step pays.

use seerattn::gate;
use seerattn::kvcache::{KcompCache, PagedKvPool, SeqKv};
use seerattn::model::ModelConfig;
use seerattn::sparse::quest::QuestMeta;
use seerattn::sparse::topk::topk_indices;
use seerattn::util::bench::bench;
use seerattn::util::rng::Rng;

fn cfg() -> ModelConfig {
    ModelConfig {
        vocab: 256, d_model: 256, n_layers: 4, n_heads: 8, n_kv_heads: 2,
        head_dim: 32, mlp_hidden: 512, rope_theta: 10000.0, rms_eps: 1e-5,
        d_gate: 32, block_size: 16, max_seq: 512, group_size: 4,
    }
}

fn main() {
    let c = cfg();
    let bs = c.block_size;
    let mut rng = Rng::new(1);
    let wk: Vec<f32> = (0..c.n_kv_heads * 3 * c.head_dim * c.d_gate)
        .map(|_| rng.normal() as f32)
        .collect();
    let k_block: Vec<f32> = (0..c.n_kv_heads * bs * c.head_dim)
        .map(|_| rng.normal() as f32)
        .collect();
    let qg: Vec<f32> = (0..c.n_kv_heads * c.d_gate).map(|_| rng.normal() as f32).collect();
    let q: Vec<f32> = (0..c.head_dim).map(|_| rng.normal() as f32).collect();
    println!("AttnGate host-side overhead (per token / per layer / per seq)\n");

    let r = bench("kcomp update (1 block flush)", 10, 100, 0.3, || {
        std::hint::black_box(gate::kcomp_entry(&c, &wk, &k_block, bs, 64));
    });
    println!("{}", r.report());

    // Gate scoring against a full 512-token context (32 entries).
    let mut kcache = KcompCache::new(&c, bs);
    let krow: Vec<f32> = (0..c.n_kv_heads * c.head_dim).map(|_| rng.normal() as f32).collect();
    for _ in 0..c.max_seq {
        kcache.append(&c, &wk, &krow);
    }
    let r = bench("gate score (32 blocks) + top-8", 10, 100, 0.3, || {
        let scores = kcache.score(&c, &qg);
        for row in &scores {
            std::hint::black_box(topk_indices(row, 8));
        }
    });
    println!("{}", r.report());

    let mut quest = QuestMeta::new(&c, bs, c.max_seq);
    for _ in 0..c.max_seq {
        quest.append(&krow);
    }
    let r = bench("quest score (32 blocks, 8 q-heads) + top-8", 10, 100, 0.3, || {
        for _qh in 0..c.n_heads {
            let scores = quest.scores(0, &q);
            std::hint::black_box(topk_indices(&scores, 8));
        }
    });
    println!("{}", r.report());

    // Dense-vs-sparse gather (the engine's step-4 staging memcpy).
    let mut pool = PagedKvPool::new(64, c.n_kv_heads, c.head_dim, bs);
    let mut seq = SeqKv::new();
    let vrow = krow.clone();
    for _ in 0..c.max_seq {
        seq.append(&mut pool, &krow, &vrow).unwrap();
    }
    let mut kbuf = vec![0f32; c.n_kv_heads * c.max_seq * c.head_dim];
    let mut vbuf = kbuf.clone();
    let r = bench("gather DENSE cache (512 tok x 2 heads)", 10, 100, 0.3, || {
        for h in 0..c.n_kv_heads {
            for (blk, &pg) in seq.pages.iter().enumerate() {
                let off = (h * c.max_seq + blk * bs) * c.head_dim;
                pool.gather_block(pg, h, bs, &mut kbuf[off..off + bs * c.head_dim],
                                  &mut vbuf[off..off + bs * c.head_dim]);
            }
        }
        std::hint::black_box(&kbuf);
    });
    println!("{}", r.report());
    let r = bench("gather SPARSE budget 128 (8 blocks x 2 heads)", 10, 100, 0.3, || {
        for h in 0..c.n_kv_heads {
            for blk in [0usize, 3, 7, 11, 15, 19, 23, 31] {
                let off = (h * c.max_seq + blk * bs) * c.head_dim;
                pool.gather_block(seq.pages[blk], h, bs,
                                  &mut kbuf[off..off + bs * c.head_dim],
                                  &mut vbuf[off..off + bs * c.head_dim]);
            }
        }
        std::hint::black_box(&kbuf);
    });
    println!("{}", r.report());
    println!("\n(gate scoring + selection is microseconds — negligible next \
              to attention, matching the paper's overhead claim)");
}
