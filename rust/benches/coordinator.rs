//! Coordinator benchmark: end-to-end decode step latency under each
//! policy, plus the share spent outside the XLA executables (the L3
//! coordination overhead target in DESIGN.md §8).

use std::rc::Rc;

use seerattn::coordinator::{EngineConfig, Request};
use seerattn::harness;
use seerattn::runtime::Runtime;
use seerattn::sparse::Policy;
use seerattn::util::rng::Rng;
use seerattn::workload::reasoning::{generate, TaskConfig};
use seerattn::workload::Vocab;

fn main() {
    if !harness::artifacts_available() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    }
    let dir = harness::artifacts_dir();
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let vocab = Vocab::default();
    println!("decode-step latency at full batch (8 x ~450-token contexts)\n");
    println!("{:<26} {:>12} {:>12} {:>14} {:>12}",
             "policy", "decode p50", "decode p95", "xla share", "prefill p50");
    for (name, policy) in [
        ("dense", Policy::Dense),
        ("seer b=64", Policy::GateBudget { budget_tokens: 64 }),
        ("seer b=128", Policy::GateBudget { budget_tokens: 128 }),
        ("seer b=256", Policy::GateBudget { budget_tokens: 256 }),
        ("seer thresh=0.04", Policy::GateThreshold { threshold: 0.04 }),
        ("oracle b=128", Policy::Oracle { budget_tokens: 128 }),
        ("quest b=128", Policy::Quest { budget_tokens: 128 }),
    ] {
        let ecfg = EngineConfig { policy, block_size: 16, ..Default::default() };
        let mut eng = harness::build_engine(&rt, &dir, ecfg).unwrap();
        let mut rng = Rng::new(3);
        let task = TaskConfig::hard();
        for i in 0..eng.batch_size() {
            let ep = generate(&vocab, &task, &mut rng);
            eng.submit(Request::new(i as u64, ep.prompt, 40));
        }
        let xla0 = eng.rt.stats().execute_s;
        let t0 = std::time::Instant::now();
        eng.run_to_completion().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let xla = eng.rt.stats().execute_s - xla0;
        println!(
            "{name:<26} {:>9.2} ms {:>9.2} ms {:>13.1}% {:>9.2} ms",
            eng.metrics.decode_step_s.median() * 1e3,
            eng.metrics.decode_step_s.percentile(95.0) * 1e3,
            100.0 * xla / wall,
            eng.metrics.prefill_s.median() * 1e3,
        );
    }
    println!("\n(xla share = fraction of wall time inside executables; the \
              rest is the L3 coordinator: gather, selection, cache updates)");
}
