//! Decode hot-path benchmark: one synthetic decode step (gate scoring,
//! block selection, staged gather) per policy, **optimized vs the seed
//! implementation in the same run**, a per-stage breakdown
//! (score / softmax / select / gather), a same-run **SIMD vs
//! forced-scalar** comparison per policy, plus a steady-state
//! allocation check.
//!
//! The paper's speedup argument is that sparse decode cost scales with
//! the token budget, not the context; this bench measures the host-side
//! coordinator work that must stay negligible for that to hold. The
//! "reference" closures reproduce the seed's behaviour exactly: fresh
//! `vec![0f32; ..]` staging per call, `Vec`-returning score/top-k paths,
//! and per-head selection clones. The "optimized" closures use the
//! persistent [`StagingArena`], `*_into` scoring over the
//! runtime-dispatched SIMD kernels (`util::simd`), and
//! `select_nth_unstable_by` partial top-k — and are asserted to perform
//! **zero heap allocation** in steady state via a counting global
//! allocator. Before timing the SIMD section, scores, selections, and
//! staged buffers are asserted **bit-identical** between auto-dispatch
//! and the forced-scalar path.
//!
//! Writes `BENCH_decode.json` at the repo root (next PRs diff against
//! it); the `config.simd` block records the CPU features and dispatch
//! target so numbers are comparable across machines. Everything is
//! seeded; pure host code, no PJRT needed.

use seerattn::coordinator::gather::{gather_one_dense, gather_one_sparse,
                                    gather_sparse_into, DenseGeom, GatherJob,
                                    GatherPool, SparseGeom};
use seerattn::coordinator::StagingArena;
use seerattn::gate;
use seerattn::kvcache::{KcompCache, PagedKvPool, SeqKv};
use seerattn::model::ModelConfig;
use seerattn::sparse::policy::{select_budget, select_budget_into,
                               select_threshold, select_threshold_into,
                               SelKind, SelectionBuf};
use seerattn::sparse::quest::QuestMeta;
use seerattn::sparse::topk::{merge_mandatory, topk_indices, TopkScratch};
use seerattn::util::alloc_count::{count_allocs, CountingAlloc};
use seerattn::util::bench::{bench, BenchResult};
use seerattn::util::json::Json;
use seerattn::util::rng::Rng;
use seerattn::util::simd;

// Counting allocator (shared harness, see util::alloc_count): only
// counts while armed, so the bench's own bookkeeping (Series pushes,
// JSON building) stays out of the tally.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// ---------------------------------------------------------------------
// Synthetic decode-step state (mirrors one engine layer at full batch).
// ---------------------------------------------------------------------

fn cfg() -> ModelConfig {
    ModelConfig {
        vocab: 256, d_model: 256, n_layers: 4, n_heads: 8, n_kv_heads: 2,
        head_dim: 32, mlp_hidden: 512, rope_theta: 10000.0, rms_eps: 1e-5,
        d_gate: 32, block_size: 16, max_seq: 512, group_size: 4,
    }
}

const BATCH: usize = 4;
/// Context per slot; deliberately not a block multiple so the mandatory
/// partial last block is exercised.
const CTX: usize = 487;
const BUDGET_TOKENS: usize = 128;
/// Threshold-mode cutoff, shared by the fused step, the stage-isolated
/// select closure, and the seed reference so they measure one workload.
const THRESHOLD: f32 = 0.04;
/// Compiled staging variants a real manifest would carry.
const SEL_VARIANTS: [usize; 4] = [64, 128, 256, 512];

struct SlotState {
    kv: SeqKv,
    kcomp: KcompCache,
    quest: QuestMeta,
    q_gate: Vec<f32>,   // [hkv, dg]
    q_rope: Vec<f32>,   // [h_all, dh]
}

struct Fixture {
    c: ModelConfig,
    pool: PagedKvPool,
    slots: Vec<SlotState>,
}

fn build_fixture(seed: u64) -> Fixture {
    let c = cfg();
    let bs = c.block_size;
    let mut rng = Rng::new(seed);
    let pages_per_seq = c.max_seq / bs + 1;
    let mut pool = PagedKvPool::new(BATCH * pages_per_seq, c.n_kv_heads,
                                    c.head_dim, bs);
    let wk: Vec<f32> = (0..c.n_kv_heads * 3 * c.head_dim * c.d_gate)
        .map(|_| rng.normal() as f32)
        .collect();
    let mut slots = Vec::with_capacity(BATCH);
    for _ in 0..BATCH {
        let mut kv = SeqKv::new();
        let mut kcomp = KcompCache::new(&c, bs);
        let mut quest = QuestMeta::new(&c, bs, c.max_seq);
        for _ in 0..CTX {
            let k: Vec<f32> = (0..c.n_kv_heads * c.head_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            let v: Vec<f32> = (0..c.n_kv_heads * c.head_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            kv.append(&mut pool, &k, &v).unwrap();
            quest.append(&k);
            kcomp.append(&c, &wk, &k);
        }
        let q_gate: Vec<f32> = (0..c.n_kv_heads * c.d_gate)
            .map(|_| rng.normal() as f32)
            .collect();
        let q_rope: Vec<f32> = (0..c.n_heads * c.head_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        slots.push(SlotState { kv, kcomp, quest, q_gate, q_rope });
    }
    Fixture { c, pool, slots }
}

fn sel_variant_for(tokens: usize) -> usize {
    SEL_VARIANTS
        .iter()
        .copied()
        .filter(|t| *t >= tokens)
        .min()
        .unwrap_or(SEL_VARIANTS[SEL_VARIANTS.len() - 1])
}

// ---------------------------------------------------------------------
// Optimized step: arena staging + scratch selection (the engine's path).
// ---------------------------------------------------------------------

/// Everything the optimized step reuses across iterations.
#[derive(Default)]
struct HotState {
    arena: StagingArena,
    topk: TopkScratch,
    scores: Vec<Vec<f32>>,
    quest_row: Vec<f32>,
    sel_bufs: Vec<SelectionBuf>,
}

#[derive(Clone, Copy, PartialEq)]
enum BenchPolicy {
    Dense,
    GateBudget,
    GateThreshold,
    Quest,
}

impl BenchPolicy {
    fn name(self) -> &'static str {
        match self {
            BenchPolicy::Dense => "dense",
            BenchPolicy::GateBudget => "seer-budget",
            BenchPolicy::GateThreshold => "seer-threshold",
            BenchPolicy::Quest => "quest",
        }
    }
}

/// One optimized decode step: select per slot, then gather into the
/// arena. Returns staged bytes (for reporting / black-boxing).
fn hot_step(fx: &Fixture, policy: BenchPolicy, st: &mut HotState) -> u64 {
    let c = &fx.c;
    let bs = c.block_size;
    let (h_all, dh, g) = (c.n_heads, c.head_dim, c.group_size);
    if st.sel_bufs.len() < BATCH {
        st.sel_bufs.resize_with(BATCH, SelectionBuf::new);
    }
    // Selection.
    for (i, slot) in fx.slots.iter().enumerate() {
        let buf = &mut st.sel_bufs[i];
        let kc = &slot.kcomp;
        let partial = if kc.has_partial() { Some(kc.partial_index()) } else { None };
        let n_complete = kc.n_complete();
        match policy {
            BenchPolicy::Dense => buf.set_dense(),
            BenchPolicy::GateBudget => {
                kc.score_into(&slot.q_gate, &mut st.scores);
                let k = (BUDGET_TOKENS / bs).max(1);
                select_budget_into(&st.scores, k, partial, &mut st.topk, buf);
            }
            BenchPolicy::GateThreshold => {
                kc.score_into(&slot.q_gate, &mut st.scores);
                for row in &mut st.scores {
                    let n = row.len();
                    if n > 0 {
                        gate::softmax_rows(row, n);
                    }
                }
                select_threshold_into(&st.scores, THRESHOLD, partial, buf);
            }
            BenchPolicy::Quest => {
                let k = (BUDGET_TOKENS / bs).max(1);
                let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                buf.begin(SelKind::PerHead, h_all);
                for qh in 0..h_all {
                    let kvh = qh / g;
                    let q = &slot.q_rope[qh * dh..(qh + 1) * dh];
                    slot.quest.scores_into(kvh, q, &mut st.quest_row);
                    let sel = buf.row_mut(qh);
                    let n = n_complete.min(st.quest_row.len());
                    st.topk.topk_into(&st.quest_row[..n], take, sel);
                    if let Some(p) = partial {
                        merge_mandatory(sel, p);
                    }
                }
            }
        }
    }
    gather_stage(fx, policy, st)
}

/// The gather half of [`hot_step`] — also timed in isolation for the
/// per-stage breakdown. Goes through the exact production helpers the
/// engine's serial path uses (coordinator::gather), so the bench times
/// the shipped gather code, not a copy of it.
fn gather_stage(fx: &Fixture, policy: BenchPolicy, st: &mut HotState) -> u64 {
    let c = &fx.c;
    let bs = c.block_size;
    let (hkv, h_all, dh, g) = (c.n_kv_heads, c.n_heads, c.head_dim, c.group_size);
    let mut staged = 0u64;
    if policy == BenchPolicy::Dense {
        let s = c.max_seq;
        let set = st.arena.dense(BATCH, hkv, s, dh);
        let geom = DenseGeom { hkv, block_size: bs, max_seq: s, dh };
        let (kc, vc, seq_len, dirty) = set.parts_mut();
        let row_kv = hkv * s * dh;
        for (i, slot) in fx.slots.iter().enumerate() {
            let job = GatherJob { row: i, kv: &slot.kv, sel: &st.sel_bufs[i] };
            gather_one_dense(&fx.pool, &job, &geom,
                             &mut kc[i * row_kv..(i + 1) * row_kv],
                             &mut vc[i * row_kv..(i + 1) * row_kv],
                             &mut seq_len[i..i + 1],
                             &mut dirty[i * hkv..(i + 1) * hkv]);
            staged += 2 * (slot.kv.len * dh * 4) as u64 * hkv as u64;
        }
    } else {
        let per_head = policy == BenchPolicy::Quest;
        let heads = if per_head { h_all } else { hkv };
        let mut max_tokens = 1usize;
        for (i, buf) in st.sel_bufs[..BATCH].iter().enumerate() {
            for row in buf.rows() {
                let t: usize = row
                    .iter()
                    .map(|&j| fx.slots[i].kv.tokens_in_block(j as usize, bs))
                    .sum();
                max_tokens = max_tokens.max(t);
            }
        }
        let t_cap = sel_variant_for(max_tokens);
        let set = st.arena.sparse(BATCH, heads, t_cap, dh);
        let geom = SparseGeom { heads, group: g, per_head, block_size: bs,
                                t_cap, dh };
        let (k_sel, v_sel, mask, dirty) = set.parts_mut();
        let row_kv = heads * t_cap * dh;
        let row_m = heads * t_cap;
        for (i, slot) in fx.slots.iter().enumerate() {
            let job = GatherJob { row: i, kv: &slot.kv, sel: &st.sel_bufs[i] };
            gather_one_sparse(&fx.pool, &job, &geom,
                              &mut k_sel[i * row_kv..(i + 1) * row_kv],
                              &mut v_sel[i * row_kv..(i + 1) * row_kv],
                              &mut mask[i * row_m..(i + 1) * row_m],
                              &mut dirty[i * heads..(i + 1) * heads]);
            let t: usize = dirty[i * heads..(i + 1) * heads].iter().sum();
            staged += 2 * (t * dh * 4) as u64;
        }
    }
    staged
}

// ---------------------------------------------------------------------
// Stage-isolated closures for the per-stage breakdown. Each is
// idempotent (safe to call repeatedly under the timer) and
// allocation-free once warmed.
// ---------------------------------------------------------------------

/// Pristine per-slot score rows, computed once outside the timers:
/// raw gate rows, softmaxed gate rows, and per-query-head Quest rows.
struct PreparedScores {
    raw: Vec<Vec<Vec<f32>>>,
    softmaxed: Vec<Vec<Vec<f32>>>,
    quest: Vec<Vec<Vec<f32>>>,
}

fn prepare_scores(fx: &Fixture) -> PreparedScores {
    let c = &fx.c;
    let (h_all, dh, g) = (c.n_heads, c.head_dim, c.group_size);
    let mut raw = Vec::new();
    let mut softmaxed = Vec::new();
    let mut quest = Vec::new();
    for slot in &fx.slots {
        let mut rows = Vec::new();
        slot.kcomp.score_into(&slot.q_gate, &mut rows);
        raw.push(rows.clone());
        for row in &mut rows {
            let n = row.len();
            if n > 0 {
                gate::softmax_rows(row, n);
            }
        }
        softmaxed.push(rows);
        let mut qrows = Vec::new();
        for qh in 0..h_all {
            let mut out = Vec::new();
            let q = &slot.q_rope[qh * dh..(qh + 1) * dh];
            slot.quest.scores_into(qh / g, q, &mut out);
            qrows.push(out);
        }
        quest.push(qrows);
    }
    PreparedScores { raw, softmaxed, quest }
}

/// Scoring only: gate dot-product sweeps (or Quest min/max bounds).
/// This is the stage the SIMD kernels accelerate most directly.
fn stage_score(fx: &Fixture, policy: BenchPolicy, st: &mut HotState) {
    let c = &fx.c;
    let (h_all, dh, g) = (c.n_heads, c.head_dim, c.group_size);
    match policy {
        BenchPolicy::Dense => {}
        BenchPolicy::GateBudget | BenchPolicy::GateThreshold => {
            for slot in &fx.slots {
                slot.kcomp.score_into(&slot.q_gate, &mut st.scores);
            }
        }
        BenchPolicy::Quest => {
            for slot in &fx.slots {
                for qh in 0..h_all {
                    let q = &slot.q_rope[qh * dh..(qh + 1) * dh];
                    slot.quest.scores_into(qh / g, q, &mut st.quest_row);
                    std::hint::black_box(&st.quest_row);
                }
            }
        }
    }
}

/// Softmax only (threshold mode): refill scratch from the pristine raw
/// rows, then softmax in place. The refill copy is part of the timed
/// closure (it is what makes repeated timing possible) but is a small
/// fraction of the exp-dominated stage.
fn stage_softmax(prep: &PreparedScores, st: &mut HotState) {
    for src in &prep.raw {
        seerattn::util::buf::resize_rows(&mut st.scores, src.len());
        for (dst, s) in st.scores.iter_mut().zip(src) {
            dst.resize(s.len(), 0.0);
            dst.copy_from_slice(s);
            let n = dst.len();
            if n > 0 {
                gate::softmax_rows(dst, n);
            }
        }
    }
}

/// Selection only, over pristine (pre-scored, pre-softmaxed) rows.
fn stage_select(fx: &Fixture, policy: BenchPolicy, prep: &PreparedScores,
                st: &mut HotState) {
    let c = &fx.c;
    let bs = c.block_size;
    let h_all = c.n_heads;
    if st.sel_bufs.len() < BATCH {
        st.sel_bufs.resize_with(BATCH, SelectionBuf::new);
    }
    for (i, slot) in fx.slots.iter().enumerate() {
        let kc = &slot.kcomp;
        let partial = if kc.has_partial() { Some(kc.partial_index()) } else { None };
        let n_complete = kc.n_complete();
        let buf = &mut st.sel_bufs[i];
        match policy {
            BenchPolicy::Dense => buf.set_dense(),
            BenchPolicy::GateBudget => {
                let k = (BUDGET_TOKENS / bs).max(1);
                select_budget_into(&prep.raw[i], k, partial, &mut st.topk, buf);
            }
            BenchPolicy::GateThreshold => {
                select_threshold_into(&prep.softmaxed[i], THRESHOLD, partial, buf);
            }
            BenchPolicy::Quest => {
                let k = (BUDGET_TOKENS / bs).max(1);
                let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                buf.begin(SelKind::PerHead, h_all);
                for qh in 0..h_all {
                    let row = &prep.quest[i][qh];
                    let sel = buf.row_mut(qh);
                    let n = n_complete.min(row.len());
                    st.topk.topk_into(&row[..n], take, sel);
                    if let Some(p) = partial {
                        merge_mandatory(sel, p);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// SIMD-vs-scalar bit identity: scores, selections, staged buffers.
// ---------------------------------------------------------------------

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

struct Snapshot {
    scores: Vec<Vec<u32>>,
    sels: Vec<Vec<Vec<i32>>>,
    staged_k: Vec<u32>,
    staged_v: Vec<u32>,
    staged_mask: Vec<u32>,
    dirty: Vec<usize>,
}

/// One full step in the *current* dispatch mode, capturing everything
/// the acceptance criteria require to be mode-invariant.
fn snapshot(fx: &Fixture, policy: BenchPolicy) -> Snapshot {
    let c = &fx.c;
    let (hkv, h_all, bs) = (c.n_kv_heads, c.n_heads, c.block_size);
    let mut st = HotState::default();
    hot_step(fx, policy, &mut st);
    let prep = prepare_scores(fx);
    let mut scores = Vec::new();
    for i in 0..BATCH {
        let rows = match policy {
            BenchPolicy::Dense => continue,
            BenchPolicy::GateBudget => &prep.raw[i],
            BenchPolicy::GateThreshold => &prep.softmaxed[i],
            BenchPolicy::Quest => &prep.quest[i],
        };
        for row in rows {
            scores.push(bits(row));
        }
    }
    let sels: Vec<Vec<Vec<i32>>> = st.sel_bufs[..BATCH]
        .iter()
        .map(|b| b.rows().to_vec())
        .collect();
    let (staged_k, staged_v, staged_mask, dirty) = if policy == BenchPolicy::Dense {
        let set = st.arena.dense_peek().expect("dense set staged");
        (bits(set.k.as_f32().unwrap()), bits(set.v.as_f32().unwrap()),
         Vec::new(), set.dirty().to_vec())
    } else {
        let per_head = policy == BenchPolicy::Quest;
        let heads = if per_head { h_all } else { hkv };
        let mut max_tokens = 1usize;
        for (i, buf) in st.sel_bufs[..BATCH].iter().enumerate() {
            for row in buf.rows() {
                let t: usize = row
                    .iter()
                    .map(|&j| fx.slots[i].kv.tokens_in_block(j as usize, bs))
                    .sum();
                max_tokens = max_tokens.max(t);
            }
        }
        let t_cap = sel_variant_for(max_tokens);
        let set = st.arena.sparse_peek(heads, t_cap).expect("sparse set staged");
        (bits(set.k.as_f32().unwrap()), bits(set.v.as_f32().unwrap()),
         bits(set.mask.as_f32().unwrap()), set.dirty().to_vec())
    };
    Snapshot { scores, sels, staged_k, staged_v, staged_mask, dirty }
}

fn assert_dispatch_bit_identity(fx: &Fixture, policy: BenchPolicy) {
    simd::set_scalar(true);
    let s = snapshot(fx, policy);
    simd::set_scalar(false);
    let v = snapshot(fx, policy);
    let name = policy.name();
    assert_eq!(s.scores, v.scores, "{name}: scores diverged across dispatch");
    assert_eq!(s.sels, v.sels, "{name}: selections diverged across dispatch");
    assert_eq!(s.staged_k, v.staged_k, "{name}: staged K diverged");
    assert_eq!(s.staged_v, v.staged_v, "{name}: staged V diverged");
    assert_eq!(s.staged_mask, v.staged_mask, "{name}: staged mask diverged");
    assert_eq!(s.dirty, v.dirty, "{name}: dirty extents diverged");
}

// ---------------------------------------------------------------------
// Reference step: the seed implementation — fresh full-size zeroed
// staging, Vec-returning scores/top-k, per-head row clones.
// ---------------------------------------------------------------------

fn ref_step(fx: &Fixture, policy: BenchPolicy) -> u64 {
    let c = &fx.c;
    let bs = c.block_size;
    let (hkv, h_all, dh, g) = (c.n_kv_heads, c.n_heads, c.head_dim, c.group_size);
    // Selection (allocating, as in the seed engine).
    let mut selections: Vec<(bool, Vec<Vec<i32>>)> = Vec::new();
    for slot in &fx.slots {
        let kc = &slot.kcomp;
        let partial = if kc.has_partial() { Some(kc.partial_index()) } else { None };
        let n_complete = kc.n_complete();
        match policy {
            BenchPolicy::Dense => selections.push((false, Vec::new())),
            BenchPolicy::GateBudget => {
                let scores = kc.score(c, &slot.q_gate);
                let k = (BUDGET_TOKENS / bs).max(1);
                selections.push((false, select_budget(&scores, k, partial)));
            }
            BenchPolicy::GateThreshold => {
                let mut scores = kc.score(c, &slot.q_gate);
                for row in &mut scores {
                    let n = row.len();
                    if n > 0 {
                        gate::softmax_rows(row, n);
                    }
                }
                selections.push((false, select_threshold(&scores, THRESHOLD, partial)));
            }
            BenchPolicy::Quest => {
                let k = (BUDGET_TOKENS / bs).max(1);
                let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                let mut sel = Vec::with_capacity(h_all);
                for qh in 0..h_all {
                    let kvh = qh / g;
                    let q = &slot.q_rope[qh * dh..(qh + 1) * dh];
                    let scores = slot.quest.scores(kvh, q);
                    let n = n_complete.min(scores.len());
                    let mut s = topk_indices(&scores[..n], take);
                    if let Some(p) = partial {
                        merge_mandatory(&mut s, p);
                    }
                    sel.push(s);
                }
                selections.push((true, sel));
            }
        }
    }
    // Gather (fresh zero-filled buffers every step, as in the seed).
    let mut staged = 0u64;
    if policy == BenchPolicy::Dense {
        let s = c.max_seq;
        let mut kc = vec![0f32; BATCH * hkv * s * dh];
        let mut vc = vec![0f32; BATCH * hkv * s * dh];
        let mut seq_len = vec![0i32; BATCH];
        for (i, slot) in fx.slots.iter().enumerate() {
            seq_len[i] = slot.kv.len as i32;
            for h in 0..hkv {
                for (blk, &pg) in slot.kv.pages.iter().enumerate() {
                    let n = slot.kv.tokens_in_block(blk, bs);
                    let off = ((i * hkv + h) * s + blk * bs) * dh;
                    fx.pool.gather_block(pg, h, n, &mut kc[off..off + n * dh],
                                         &mut vc[off..off + n * dh]);
                    staged += 2 * (n * dh * 4) as u64;
                }
            }
        }
        std::hint::black_box((&kc, &vc, &seq_len));
    } else {
        let per_head = policy == BenchPolicy::Quest;
        let heads = if per_head { h_all } else { hkv };
        let mut max_tokens = 1usize;
        for (i, (_, rows)) in selections.iter().enumerate() {
            for row in rows {
                let t: usize = row
                    .iter()
                    .map(|&j| fx.slots[i].kv.tokens_in_block(j as usize, bs))
                    .sum();
                max_tokens = max_tokens.max(t);
            }
        }
        let t_cap = sel_variant_for(max_tokens);
        let mut k_sel = vec![0f32; BATCH * heads * t_cap * dh];
        let mut v_sel = vec![0f32; BATCH * heads * t_cap * dh];
        let mut mask = vec![0f32; BATCH * heads * t_cap];
        for (i, slot) in fx.slots.iter().enumerate() {
            // Seed behaviour: clone rows (expanding per head if needed).
            let rows: Vec<Vec<i32>> = if selections[i].0 {
                selections[i].1.clone()
            } else if per_head {
                (0..h_all).map(|qh| selections[i].1[qh / g].clone()).collect()
            } else {
                selections[i].1.clone()
            };
            for (hr, row) in rows.iter().enumerate() {
                let kv_head = if per_head { hr / g } else { hr };
                let mut cursor = 0usize;
                for &j in row {
                    let n = slot.kv.tokens_in_block(j as usize, bs);
                    let pg = slot.kv.pages[j as usize];
                    let off = ((i * heads + hr) * t_cap + cursor) * dh;
                    fx.pool.gather_block(pg, kv_head, n,
                                         &mut k_sel[off..off + n * dh],
                                         &mut v_sel[off..off + n * dh]);
                    let moff = (i * heads + hr) * t_cap + cursor;
                    for m in &mut mask[moff..moff + n] {
                        *m = 1.0;
                    }
                    cursor += n;
                    staged += 2 * (n * dh * 4) as u64;
                }
            }
        }
        std::hint::black_box((&k_sel, &v_sel, &mask));
    }
    staged
}

// ---------------------------------------------------------------------
// Chunked prefill: decode ITL under a mixed long-prompt + short-decode
// trace (ISSUE 7). Scheduling is driven by the deterministic SimEngine
// (step shape identical to the PJRT engine); step latency comes from a
// fixed virtual cost model, so the chunked-vs-monolithic p99 claim is
// exact and assertable even in smoke mode on a noisy runner.
// ---------------------------------------------------------------------

/// Virtual cost of one engine step: a fixed overhead (covers the decode
/// batch — every step decodes at most one token per slot) plus a linear
/// charge per prefill token staged that step. Milliseconds, arbitrary
/// but fixed; the chunked/monolithic *ratio* is the result.
const VSTEP_MS: f64 = 1.0;
const VPREFILL_TOK_MS: f64 = 0.05;

/// Run the mixed trace at the given prefill chunk (0 = monolithic) and
/// return (per-request streams, per-token ITL samples of the short
/// interactive requests) under the virtual clock.
fn chunked_prefill_run(chunk: usize) -> (Vec<(u64, Vec<i32>)>, Vec<f64>) {
    use seerattn::coordinator::{DecodeEngine, EngineEvent, Request, SimConfig,
                                SimEngine};
    let cfg = SimConfig { batch: 4, eos_every: 0, prefill_chunk: chunk,
                          ..Default::default() };
    let mut eng = SimEngine::new(cfg);
    // Three short-prompt interactive decodes — the ITL is measured on
    // their token stream...
    for id in 0..3u64 {
        eng.submit(Request::new(id, vec![2 + id as i32; 8], 64));
    }
    // ...competing with a queue of long-prompt / short-decode arrivals
    // that keep re-admitting into the fourth slot: the head-of-line
    // hazard monolithic prefill turns into an ITL spike.
    for id in 3..9u64 {
        eng.submit(Request::new(id, vec![5 + id as i32; 256], 2));
    }
    let mut itl = Vec::new();
    let mut streams: Vec<(u64, Vec<i32>)> = Vec::new();
    let mut prev_prefill = 0u64;
    while !eng.idle() {
        let mut short_toks = 0usize;
        eng.step_events(&mut |ev| match ev {
            EngineEvent::Token { id, .. } if id < 3 => short_toks += 1,
            EngineEvent::Finished(c) => streams.push((c.id, c.generated)),
            _ => {}
        }).unwrap();
        let staged = eng.metrics.prefill_tokens - prev_prefill;
        prev_prefill = eng.metrics.prefill_tokens;
        let cost = VSTEP_MS + VPREFILL_TOK_MS * staged as f64;
        for _ in 0..short_toks {
            itl.push(cost);
        }
    }
    streams.sort_by_key(|(id, _)| *id);
    (streams, itl)
}

fn chunked_prefill_json() -> Json {
    use seerattn::util::stats::Series;
    let chunk = 32usize; // multiple of every supported sparse block size
    let (streams_c, itl_c) = chunked_prefill_run(chunk);
    let (streams_m, itl_m) = chunked_prefill_run(0);
    assert_eq!(streams_c, streams_m,
               "chunked prefill changed a token stream");
    let series = |v: &[f64]| {
        let mut s = Series::new();
        for &x in v {
            s.push(x);
        }
        s
    };
    let (sc, sm) = (series(&itl_c), series(&itl_m));
    let (p99_c, p99_m) = (sc.percentile(99.0), sm.percentile(99.0));
    // The acceptance property: the 256-token monolithic admission lands
    // its full cost on some decode intervals (p99 spike); the chunked
    // run bounds every interval by one chunk.
    assert!(p99_c < p99_m,
            "chunked prefill must cut decode p99 ITL: {p99_c:.2}ms vs \
             {p99_m:.2}ms monolithic");
    println!("chunked prefill (virtual clock, chunk {chunk} vs monolithic):");
    println!("  decode ITL p50 {:.2}ms / p95 {:.2}ms / p99 {p99_c:.2}ms \
              (chunked)",
             sc.percentile(50.0), sc.percentile(95.0));
    println!("  decode ITL p50 {:.2}ms / p95 {:.2}ms / p99 {p99_m:.2}ms \
              (monolithic)",
             sm.percentile(50.0), sm.percentile(95.0));
    println!("  -> p99 x{:.2} lower, streams bit-identical\n", p99_m / p99_c);
    Json::obj(vec![
        ("prefill_chunk", Json::Num(chunk as f64)),
        ("vstep_ms", Json::Num(VSTEP_MS)),
        ("vprefill_tok_ms", Json::Num(VPREFILL_TOK_MS)),
        ("itl_p50_ms_chunked", Json::Num(sc.percentile(50.0))),
        ("itl_p95_ms_chunked", Json::Num(sc.percentile(95.0))),
        ("itl_p99_ms_chunked", Json::Num(p99_c)),
        ("itl_p50_ms_monolithic", Json::Num(sm.percentile(50.0))),
        ("itl_p95_ms_monolithic", Json::Num(sm.percentile(95.0))),
        ("itl_p99_ms_monolithic", Json::Num(p99_m)),
        ("p99_improvement", Json::Num(p99_m / p99_c)),
        ("bit_identical", Json::Bool(true)),
    ])
}

// ---------------------------------------------------------------------
// Prefix cache: TTFT under shared-prompt workloads (ISSUE 8). Same
// virtual cost model as the chunked-prefill section: the SimEngine's
// content-addressed prefix cache decides how many prompt tokens each
// admission actually stages, so the TTFT saving at every hit rate is
// exact and assertable even in smoke mode.
// ---------------------------------------------------------------------

/// Serve `n` requests one at a time, each a 256-token prompt whose
/// first `shared` tokens are common (the rest diverge per request),
/// with the prefix cache on or off. Returns (per-request TTFT ms under
/// the virtual clock, per-request streams, total prefill tokens staged,
/// total cached blocks reused).
fn prefix_cache_run(n: usize, plen: usize, shared: usize, cache: bool)
    -> (Vec<f64>, Vec<(u64, Vec<i32>)>, u64, u64) {
    use seerattn::coordinator::{DecodeEngine, EngineEvent, Request, SimConfig,
                                SimEngine};
    let cfg = SimConfig { batch: 1, eos_every: 0, prefill_chunk: 32,
                          page_tokens: 8, pages_per_slot: 128,
                          prefix_cache: cache, ..Default::default() };
    let mut eng = SimEngine::new(cfg);
    let head: Vec<i32> = (0..shared).map(|t| 9 + (t % 50) as i32).collect();
    let mut clock = 0.0f64;
    let mut ttfts = Vec::new();
    let mut streams: Vec<(u64, Vec<i32>)> = Vec::new();
    let mut prev_prefill = 0u64;
    for i in 0..n as u64 {
        let mut prompt = head.clone();
        prompt.extend((0..plen - shared)
            .map(|t| 60 + ((i as usize * 13 + t) % 60) as i32));
        eng.submit(Request::new(i, prompt, 4));
        let submitted_at = clock;
        let mut first: Option<f64> = None;
        while !eng.idle() {
            let mut saw_token = false;
            eng.step_events(&mut |ev| match ev {
                EngineEvent::Token { id, .. } if id == i => saw_token = true,
                EngineEvent::Finished(c) => streams.push((c.id, c.generated)),
                _ => {}
            }).unwrap();
            let staged = eng.metrics.prefill_tokens - prev_prefill;
            prev_prefill = eng.metrics.prefill_tokens;
            clock += VSTEP_MS + VPREFILL_TOK_MS * staged as f64;
            if saw_token && first.is_none() {
                first = Some(clock - submitted_at);
            }
        }
        ttfts.push(first.expect("request produced no token"));
    }
    (ttfts, streams, eng.metrics.prefill_tokens,
     eng.metrics.prefix_blocks_reused)
}

fn prefix_cache_json() -> Json {
    let (n, plen) = (4usize, 256usize);
    let bs = 8usize; // page_tokens in prefix_cache_run
    // Warm TTFT = mean over the repeats (the first request is the cold
    // publisher at every hit rate).
    let warm_mean = |ttfts: &[f64]| {
        ttfts[1..].iter().sum::<f64>() / (ttfts.len() - 1) as f64
    };
    println!("prefix cache (virtual clock, {plen}-token prompts, \
              shared-head sweep):");
    let mut sweep = Vec::new();
    let mut prev_warm = f64::INFINITY;
    for shared in [0usize, 64, 128, 192, 240] {
        let (t_on, s_on, toks_on, reused) =
            prefix_cache_run(n, plen, shared, true);
        let (t_off, s_off, toks_off, reused_off) =
            prefix_cache_run(n, plen, shared, false);
        assert_eq!(s_on, s_off,
                   "shared {shared}: prefix reuse changed a stream");
        assert_eq!(reused_off, 0, "cache off must not reuse");
        assert_eq!(reused, ((shared / bs) * (n - 1)) as u64,
                   "shared {shared}: every repeat must splice the whole \
                    shared head");
        assert_eq!(toks_on, toks_off - bs as u64 * reused,
                   "shared {shared}: reused blocks must come off prefill");
        let (on, off) = (warm_mean(&t_on), warm_mean(&t_off));
        assert!(on <= off + 1e-9,
                "shared {shared}: cache must not slow TTFT down");
        assert!(on <= prev_warm + 1e-9,
                "warm TTFT must fall as the shared head grows");
        prev_warm = on;
        println!("  shared {shared:>3} ({:>3.0}%): TTFT {off:>6.2}ms cold \
                  -> {on:>6.2}ms warm (x{:.2}), {reused} blocks reused",
                 100.0 * shared as f64 / plen as f64, off / on);
        sweep.push(Json::obj(vec![
            ("shared_tokens", Json::Num(shared as f64)),
            ("hit_rate", Json::Num(shared as f64 / plen as f64)),
            ("ttft_ms_cold", Json::Num(off)),
            ("ttft_ms_warm", Json::Num(on)),
            ("ttft_speedup", Json::Num(off / on)),
            ("prefill_tokens_cold", Json::Num(toks_off as f64)),
            ("prefill_tokens_warm", Json::Num(toks_on as f64)),
            ("blocks_reused", Json::Num(reused as f64)),
        ]));
    }
    println!();
    Json::obj(vec![
        ("n_requests", Json::Num(n as f64)),
        ("prompt_tokens", Json::Num(plen as f64)),
        ("block_tokens", Json::Num(bs as f64)),
        ("vstep_ms", Json::Num(VSTEP_MS)),
        ("vprefill_tok_ms", Json::Num(VPREFILL_TOK_MS)),
        ("sweep", Json::Arr(sweep)),
        ("bit_identical", Json::Bool(true)),
    ])
}

// ---------------------------------------------------------------------

fn ms(r: &BenchResult) -> Json {
    Json::Num(r.median_s * 1e3)
}

fn main() {
    let seed: u64 = std::env::var("SEERATTN_BENCH_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(17);
    // Smoke mode (CI): run every parity assert and the zero-allocation
    // check, but with minimal timed iterations, and do NOT rewrite
    // BENCH_decode.json — timings from shared runners are noise.
    let smoke = std::env::var("SEERATTN_BENCH_SMOKE").as_deref() == Ok("1");
    let (warmup, iters, budget) = if smoke { (1, 2, 0.0) } else { (5, 30, 0.4) };
    if smoke {
        println!("[smoke mode: asserts only, timings indicative, no JSON]\n");
    }
    let fx = build_fixture(seed);
    let policies = [
        BenchPolicy::Dense,
        BenchPolicy::GateBudget,
        BenchPolicy::GateThreshold,
        BenchPolicy::Quest,
    ];
    let feats = simd::cpu_features();

    println!("decode hot path: synthetic step (select + gather), batch {BATCH}, \
              ctx {CTX}, block {}, budget {BUDGET_TOKENS}", fx.c.block_size);
    println!("simd dispatch: {} (detected {}; avx2={} fma={} neon={})\n",
             simd::target_name(), simd::detected().name(), feats.avx2,
             feats.fma, feats.neon);

    let mut policy_json: Vec<(String, Json)> = Vec::new();
    let mut total_allocs = 0u64;
    for policy in policies {
        // Scores / selections / staged buffers must be bit-identical
        // between auto-dispatch and the forced-scalar fallback before
        // anything is timed.
        assert_dispatch_bit_identity(&fx, policy);

        let mut st = HotState::default();
        // Warm up: create arena sets, grow scratch to steady state.
        for _ in 0..3 {
            std::hint::black_box(hot_step(&fx, policy, &mut st));
        }
        // Steady-state allocation check: 20 full steps, zero allocs
        // (the SIMD kernels are stack-only, so this gate holds across
        // dispatch targets).
        let allocs = count_allocs(|| {
            for _ in 0..20 {
                std::hint::black_box(hot_step(&fx, policy, &mut st));
            }
        });
        total_allocs += allocs;
        assert_eq!(
            allocs, 0,
            "policy {}: steady-state decode step allocated {allocs} times",
            policy.name()
        );

        let staged = hot_step(&fx, policy, &mut st);
        let opt = bench(&format!("{} optimized", policy.name()), warmup, iters,
                        budget, || {
            std::hint::black_box(hot_step(&fx, policy, &mut st));
        });
        let reference = bench(&format!("{} reference", policy.name()), warmup,
                              iters, budget, || {
            std::hint::black_box(ref_step(&fx, policy));
        });
        println!("{}", reference.report());
        println!("{}", opt.report());
        let speedup = reference.median_s / opt.median_s.max(1e-12);
        println!("  -> speedup x{speedup:.2}, staged {staged} B/step, \
                  steady-state allocs {allocs}");

        // Per-stage breakdown (auto dispatch). Dense has no scoring or
        // softmax stage — those fields are null rather than a timing of
        // an empty closure.
        let prep = prepare_scores(&fx);
        let score = (policy != BenchPolicy::Dense).then(|| {
            bench(&format!("{} stage: score", policy.name()), warmup, iters,
                  budget, || {
                stage_score(&fx, policy, &mut st);
            })
        });
        let softmax = (policy == BenchPolicy::GateThreshold).then(|| {
            bench(&format!("{} stage: softmax", policy.name()), warmup, iters,
                  budget, || {
                stage_softmax(&prep, &mut st);
            })
        });
        let select = bench(&format!("{} stage: select", policy.name()), warmup,
                           iters, budget, || {
            stage_select(&fx, policy, &prep, &mut st);
        });
        // Re-run a full step so sel_bufs match the policy again before
        // the gather-only timer (stage_select leaves them consistent,
        // but be explicit).
        hot_step(&fx, policy, &mut st);
        let gather = bench(&format!("{} stage: gather", policy.name()), warmup,
                           iters, budget, || {
            std::hint::black_box(gather_stage(&fx, policy, &mut st));
        });
        if let Some(sc) = &score {
            println!("{}", sc.report());
        }
        if let Some(sm) = &softmax {
            println!("{}", sm.report());
        }
        println!("{}", select.report());
        println!("{}", gather.report());

        // Same-run SIMD vs forced-scalar: full step and scoring stage.
        simd::set_scalar(true);
        let step_scalar = bench(&format!("{} step (scalar)", policy.name()),
                                warmup, iters, budget, || {
            std::hint::black_box(hot_step(&fx, policy, &mut st));
        });
        let score_scalar = score.as_ref().map(|_| {
            bench(&format!("{} score (scalar)", policy.name()), warmup, iters,
                  budget, || {
                stage_score(&fx, policy, &mut st);
            })
        });
        simd::set_scalar(false);
        let simd_speedup = step_scalar.median_s / opt.median_s.max(1e-12);
        println!("{}", step_scalar.report());
        match (&score, &score_scalar) {
            (Some(sa), Some(ss)) => {
                let score_speedup = ss.median_s / sa.median_s.max(1e-12);
                println!("{}", ss.report());
                println!("  -> simd step x{simd_speedup:.2}, \
                          scoring stage x{score_speedup:.2}\n");
            }
            _ => println!("  -> simd step x{simd_speedup:.2} \
                           (no scoring stage)\n"),
        }

        let stages = Json::obj(vec![
            ("score_ms", score.as_ref().map(ms).unwrap_or(Json::Null)),
            ("softmax_ms", softmax.as_ref().map(ms).unwrap_or(Json::Null)),
            ("select_ms", ms(&select)),
            ("gather_ms", ms(&gather)),
        ]);
        let score_speedup_json = match (&score, &score_scalar) {
            (Some(sa), Some(ss)) => {
                Json::Num(ss.median_s / sa.median_s.max(1e-12))
            }
            _ => Json::Null,
        };
        let simd_json = Json::obj(vec![
            ("step_auto_ms", ms(&opt)),
            ("step_scalar_ms", ms(&step_scalar)),
            ("simd_speedup", Json::Num(simd_speedup)),
            ("score_auto_ms", score.as_ref().map(ms).unwrap_or(Json::Null)),
            ("score_scalar_ms",
             score_scalar.as_ref().map(ms).unwrap_or(Json::Null)),
            ("score_speedup", score_speedup_json),
        ]);
        policy_json.push((
            policy.name().to_string(),
            Json::obj(vec![
                ("optimized_median_ms", Json::Num(opt.median_s * 1e3)),
                ("optimized_mean_ms", Json::Num(opt.mean_s * 1e3)),
                ("reference_median_ms", Json::Num(reference.median_s * 1e3)),
                ("reference_mean_ms", Json::Num(reference.mean_s * 1e3)),
                ("speedup", Json::Num(speedup)),
                ("staged_bytes_per_step", Json::Num(staged as f64)),
                ("steady_state_allocs", Json::Num(allocs as f64)),
                ("stages", stages),
                ("simd", simd_json),
            ]),
        ));
    }

    // ------------------------------------------------------------------
    // Gather fan-out: serial vs persistent-pool parallel gather over the
    // arena's disjoint per-slot rows (same inner code; see
    // coordinator::gather). Selection state comes from one GateBudget
    // pass; correctness (bit-identity) and zero steady-state allocation
    // are asserted before timing.
    // ------------------------------------------------------------------
    let gather_json = {
        let mut st = HotState::default();
        hot_step(&fx, BenchPolicy::GateBudget, &mut st);
        let c = &fx.c;
        let (hkv, dh, bs) = (c.n_kv_heads, c.head_dim, c.block_size);
        let mut max_tokens = 1usize;
        for (i, buf) in st.sel_bufs[..BATCH].iter().enumerate() {
            for row in buf.rows() {
                let t: usize = row
                    .iter()
                    .map(|&j| fx.slots[i].kv.tokens_in_block(j as usize, bs))
                    .sum();
                max_tokens = max_tokens.max(t);
            }
        }
        let t_cap = sel_variant_for(max_tokens);
        let geom = SparseGeom { heads: hkv, group: c.group_size, per_head: false,
                                block_size: bs, t_cap, dh };
        let jobs: Vec<GatherJob> = (0..BATCH)
            .map(|i| GatherJob { row: i, kv: &fx.slots[i].kv, sel: &st.sel_bufs[i] })
            .collect();
        let threads = std::thread::available_parallelism()
            .map(|n| n.get().min(4))
            .unwrap_or(2)
            .max(2);
        // Persistent lanes, as the engine holds them: spawned once here,
        // woken per pass (no per-call thread spawn, no work-list Vec).
        let gpool = GatherPool::new(threads);
        let mut serial_arena = StagingArena::new();
        let mut parallel_arena = StagingArena::new();
        let row_kv = hkv * t_cap * dh;
        let row_m = hkv * t_cap;
        let serial_pass = |arena: &mut StagingArena| {
            let set = arena.sparse(BATCH, hkv, t_cap, dh);
            let (k, v, m, d) = set.parts_mut();
            for job in &jobs {
                let r = job.row;
                gather_one_sparse(&fx.pool, job, &geom,
                                  &mut k[r * row_kv..(r + 1) * row_kv],
                                  &mut v[r * row_kv..(r + 1) * row_kv],
                                  &mut m[r * row_m..(r + 1) * row_m],
                                  &mut d[r * hkv..(r + 1) * hkv]);
            }
        };
        let parallel_pass = |arena: &mut StagingArena| {
            let set = arena.sparse(BATCH, hkv, t_cap, dh);
            let (k, v, m, d) = set.parts_mut();
            gather_sparse_into(&fx.pool, jobs.len(), &|i| jobs[i], &geom,
                               k, v, m, d, Some(&gpool));
        };
        // Bit-identity before timing — runs the *same* closures the
        // benchmark times, then compares the staged sets via the
        // non-resetting peek accessors.
        serial_pass(&mut serial_arena);
        parallel_pass(&mut parallel_arena);
        // The persistent pool killed the per-call work-list Vec: the
        // parallel path is now steady-state allocation-free too.
        let gather_allocs = count_allocs(|| {
            for _ in 0..5 {
                parallel_pass(&mut parallel_arena);
            }
        });
        assert_eq!(gather_allocs, 0,
                   "parallel gather allocated {gather_allocs} times in steady \
                    state");
        {
            let sset = serial_arena.sparse_peek(hkv, t_cap).unwrap();
            let pset = parallel_arena.sparse_peek(hkv, t_cap).unwrap();
            assert_eq!(pset.k.as_f32().unwrap(), sset.k.as_f32().unwrap(),
                       "parallel gather k diverged");
            assert_eq!(pset.v.as_f32().unwrap(), sset.v.as_f32().unwrap(),
                       "parallel gather v diverged");
            assert_eq!(pset.mask.as_f32().unwrap(), sset.mask.as_f32().unwrap(),
                       "parallel gather mask diverged");
            assert_eq!(pset.dirty(), sset.dirty(), "parallel gather dirty diverged");
        }
        let serial = bench("gather serial", warmup, iters, budget, || {
            serial_pass(&mut serial_arena);
        });
        let parallel = bench(&format!("gather {threads} threads"), warmup, iters,
                             budget, || {
            parallel_pass(&mut parallel_arena);
        });
        println!("{}", serial.report());
        println!("{}", parallel.report());
        let speedup = serial.median_s / parallel.median_s.max(1e-12);
        println!("  -> gather fan-out x{speedup:.2} at {threads} threads \
                  (batch {BATCH}; default lanes would be {})\n",
                 GatherPool::default_lanes());
        Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("default_lanes", Json::Num(GatherPool::default_lanes() as f64)),
            ("serial_median_ms", Json::Num(serial.median_s * 1e3)),
            ("parallel_median_ms", Json::Num(parallel.median_s * 1e3)),
            ("speedup", Json::Num(speedup)),
        ])
    };

    // Deterministic virtual-clock sections — asserts run in smoke mode
    // too (no timer noise to exclude).
    let chunked_prefill = chunked_prefill_json();
    let prefix_cache = prefix_cache_json();

    let out = Json::obj(vec![
        ("bench", Json::Str("decode_hot_path".into())),
        ("seed", Json::Num(seed as f64)),
        ("config", Json::obj(vec![
            ("batch", Json::Num(BATCH as f64)),
            ("context_tokens", Json::Num(CTX as f64)),
            ("block_size", Json::Num(fx.c.block_size as f64)),
            ("budget_tokens", Json::Num(BUDGET_TOKENS as f64)),
            ("n_kv_heads", Json::Num(fx.c.n_kv_heads as f64)),
            ("n_heads", Json::Num(fx.c.n_heads as f64)),
            ("head_dim", Json::Num(fx.c.head_dim as f64)),
            // CPU feature + dispatch provenance: numbers are only
            // comparable across machines with the same target.
            ("simd", Json::obj(vec![
                ("target", Json::Str(simd::target_name().into())),
                ("detected", Json::Str(simd::detected().name().into())),
                ("avx2", Json::Bool(feats.avx2)),
                ("fma", Json::Bool(feats.fma)),
                ("neon", Json::Bool(feats.neon)),
                ("forced_scalar", Json::Bool(simd::scalar_forced())),
            ])),
        ])),
        ("steady_state_allocs_total", Json::Num(total_allocs as f64)),
        ("gather", gather_json),
        ("chunked_prefill", chunked_prefill),
        ("prefix_cache", prefix_cache),
        ("policies", Json::Obj(
            policy_json.into_iter().collect(),
        )),
    ]);
    if smoke {
        // Smoke timings come from shared CI runners; writing them would
        // churn the committed baseline with noise.
        println!("smoke mode: all asserts green, BENCH_decode.json untouched");
        return;
    }
    // BENCH_decode.json lives at the repo root (one level above the
    // crate manifest) so successive PRs diff a stable path.
    let root = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| std::path::PathBuf::from(d).parent().unwrap().to_path_buf())
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    let path = root.join("BENCH_decode.json");
    std::fs::write(&path, out.to_string()).expect("write BENCH_decode.json");
    println!("wrote {}", path.display());
}
