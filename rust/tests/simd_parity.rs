//! SIMD dispatch parity: every runtime-dispatched kernel must produce
//! **bit-identical** results on the active vector target (AVX2+FMA /
//! NEON) and the forced-scalar fallback — including every tail length —
//! and that identity must propagate end-to-end: identical gate scores,
//! Quest bounds, softmaxed rows, RoPE rotations, and served tokens on a
//! serving trace. Pure host, default feature set.
//!
//! Every test here toggles the process-global dispatch flag, so they
//! serialize on one mutex and always restore auto-dispatch before
//! releasing it. (Under `SEERATTN_SIMD=scalar` — the CI forced-scalar
//! job — both sides of each comparison run the scalar path and the
//! tests degenerate to self-checks, which is the intent: that job is
//! about the fallback not rotting.)

use std::sync::Mutex;

use seerattn::coordinator::{DecodeEngine, EngineGroup, GroupConfig, Request,
                            SimConfig, SimEngine, SubmitOutcome};
use seerattn::gate::{self, RopeTable};
use seerattn::kvcache::KcompCache;
use seerattn::model::ModelConfig;
use seerattn::sparse::quest::QuestMeta;
use seerattn::util::rng::Rng;
use seerattn::util::simd;

static MODE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with dispatch pinned to scalar (true) or auto (false),
/// restoring auto afterwards. Caller must hold [`MODE_LOCK`].
fn with_mode<R>(scalar: bool, f: impl FnOnce() -> R) -> R {
    simd::set_scalar(scalar);
    let r = f();
    simd::set_scalar(false);
    r
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Odd `head_dim`, non-multiple-of-8 even `d_gate`: every kernel's tail
/// path is live on every call.
fn odd_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 16, d_model: 16, n_layers: 1, n_heads: 4, n_kv_heads: 2,
        head_dim: 13, mlp_hidden: 16, rope_theta: 10000.0, rms_eps: 1e-5,
        d_gate: 20, block_size: 4, max_seq: 64, group_size: 2,
    }
}

// ---------------------------------------------------------------------
// Raw kernels: every length through 2*LANES+1 (both tails exercised).
// ---------------------------------------------------------------------

#[test]
fn kernels_bitwise_identical_across_dispatch_at_every_tail_length() {
    let _g = lock();
    let mut rng = Rng::new(901);
    for n in 0..=2 * simd::LANES + 1 {
        let a = randv(&mut rng, n);
        let b = randv(&mut rng, n);
        let mm = randv(&mut rng, 2 * n);

        let scalar = with_mode(true, || {
            (simd::dot(&a, &b), simd::sum(&a), simd::max(&a),
             simd::quest_ub(&a, &mm))
        });
        let auto = with_mode(false, || {
            (simd::dot(&a, &b), simd::sum(&a), simd::max(&a),
             simd::quest_ub(&a, &mm))
        });
        assert_eq!(scalar.0.to_bits(), auto.0.to_bits(), "dot n={n}");
        assert_eq!(scalar.1.to_bits(), auto.1.to_bits(), "sum n={n}");
        assert_eq!(scalar.2.to_bits(), auto.2.to_bits(), "max n={n}");
        assert_eq!(scalar.3.to_bits(), auto.3.to_bits(), "quest_ub n={n}");

        // In-place kernels: run each mode on its own copy.
        let run_inplace = |scalar_mode: bool| {
            with_mode(scalar_mode, || {
                let mut sc = a.clone();
                simd::scale(&mut sc, -1.625);
                let mut ax = b.clone();
                simd::axpy(&mut ax, &a, 0.375);
                let mut sm = a.clone();
                simd::softmax_row(&mut sm);
                let mut cp = vec![7.5f32; n];
                simd::copy(&mut cp, &b);
                let mut fl = a.clone();
                simd::fill(&mut fl, 0.1);
                (sc, ax, sm, cp, fl)
            })
        };
        let s = run_inplace(true);
        let v = run_inplace(false);
        assert_eq!(bits(&s.0), bits(&v.0), "scale n={n}");
        assert_eq!(bits(&s.1), bits(&v.1), "axpy n={n}");
        assert_eq!(bits(&s.2), bits(&v.2), "softmax n={n}");
        assert_eq!(bits(&s.3), bits(&v.3), "copy n={n}");
        assert_eq!(bits(&s.4), bits(&v.4), "fill n={n}");
    }
    // RoPE rotation: even lengths only (interleaved pairs).
    for half in 0..=simd::LANES + 1 {
        let n = 2 * half;
        let row = randv(&mut rng, n);
        let cos2 = randv(&mut rng, n);
        let nsin2 = randv(&mut rng, n);
        let run = |scalar_mode: bool| {
            with_mode(scalar_mode, || {
                let mut r = row.clone();
                simd::rope_rotate(&mut r, &cos2, &nsin2);
                r
            })
        };
        assert_eq!(bits(&run(true)), bits(&run(false)), "rope n={n}");
    }
}

#[test]
fn dot_rows_bitwise_identical_across_dispatch_at_odd_dims() {
    let _g = lock();
    let mut rng = Rng::new(902);
    for d in [1usize, 3, 7, 8, 9, 13, 17, 20] {
        let q = randv(&mut rng, d);
        let rows = randv(&mut rng, 6 * d);
        let run = |scalar_mode: bool| {
            with_mode(scalar_mode, || {
                let mut out = vec![0f32; 6];
                simd::dot_rows(&q, &rows, d, 0.25, &mut out);
                out
            })
        };
        assert_eq!(bits(&run(true)), bits(&run(false)), "dot_rows d={d}");
    }
}

// ---------------------------------------------------------------------
// Module level: gate scoring, Quest, softmax, RoPE through their real
// call sites, at odd dims, across dispatch modes.
// ---------------------------------------------------------------------

#[test]
fn kcomp_scores_bitwise_identical_across_dispatch() {
    let _g = lock();
    let c = odd_cfg();
    let mut rng = Rng::new(903);
    let wk = randv(&mut rng, c.n_kv_heads * 3 * c.head_dim * c.d_gate);
    let tokens: Vec<Vec<f32>> =
        (0..23).map(|_| randv(&mut rng, c.n_kv_heads * c.head_dim)).collect();
    let queries: Vec<Vec<f32>> =
        (0..23).map(|_| randv(&mut rng, c.n_kv_heads * c.d_gate)).collect();
    let run = |scalar_mode: bool| {
        with_mode(scalar_mode, || {
            // Build the cache inside the mode too: flushes (pool +
            // axpy projection + RoPE) must also be mode-invariant.
            let mut kc = KcompCache::new(&c, c.block_size);
            let mut all_scores: Vec<Vec<u32>> = Vec::new();
            let mut buf: Vec<Vec<f32>> = Vec::new();
            for (k, q) in tokens.iter().zip(&queries) {
                kc.append(&c, &wk, k);
                kc.score_into(q, &mut buf);
                for row in &buf {
                    all_scores.push(bits(row));
                }
            }
            (all_scores, bits(kc.entries_raw()))
        })
    };
    let (s_scores, s_entries) = run(true);
    let (v_scores, v_entries) = run(false);
    assert_eq!(s_entries, v_entries, "kcomp entries diverged across dispatch");
    assert_eq!(s_scores, v_scores, "gate scores diverged across dispatch");
}

#[test]
fn quest_scores_bitwise_identical_across_dispatch() {
    let _g = lock();
    let c = odd_cfg();
    let mut rng = Rng::new(904);
    let tokens: Vec<Vec<f32>> =
        (0..19).map(|_| randv(&mut rng, c.n_kv_heads * c.head_dim)).collect();
    let q = randv(&mut rng, c.head_dim);
    let run = |scalar_mode: bool| {
        with_mode(scalar_mode, || {
            let mut m = QuestMeta::new(&c, c.block_size, c.max_seq);
            let mut out = Vec::new();
            let mut all = Vec::new();
            for k in &tokens {
                m.append(k);
                for h in 0..c.n_kv_heads {
                    m.scores_into(h, &q, &mut out);
                    all.push(bits(&out));
                }
            }
            all
        })
    };
    assert_eq!(run(true), run(false), "quest bounds diverged across dispatch");
}

#[test]
fn softmax_rows_bitwise_identical_across_dispatch() {
    let _g = lock();
    let mut rng = Rng::new(905);
    for n in 1..=2 * simd::LANES + 1 {
        let rows = randv(&mut rng, 3 * n);
        let run = |scalar_mode: bool| {
            with_mode(scalar_mode, || {
                let mut x = rows.clone();
                gate::softmax_rows(&mut x, n);
                x
            })
        };
        assert_eq!(bits(&run(true)), bits(&run(false)), "softmax n={n}");
    }
}

#[test]
fn rope_table_bitwise_identical_across_dispatch_and_to_reference() {
    let _g = lock();
    let mut rng = Rng::new(906);
    for &dim in &[2usize, 4, 10, 16, 20, 26] {
        let table = RopeTable::new(dim, 10000.0);
        for _ in 0..8 {
            let x = randv(&mut rng, dim * 3);
            let pos = rng.below(100_000) as i64;
            let run = |scalar_mode: bool| {
                with_mode(scalar_mode, || {
                    let mut y = x.clone();
                    table.apply(&mut y, pos);
                    y
                })
            };
            let s = run(true);
            let v = run(false);
            assert_eq!(bits(&s), bits(&v), "rope dim={dim} pos={pos}");
            // And both equal the freq-recomputing reference.
            let mut r = x.clone();
            gate::rope_inplace(&mut r, dim, pos, 10000.0);
            assert_eq!(bits(&s), bits(&r), "rope vs reference dim={dim}");
        }
    }
}

// ---------------------------------------------------------------------
// End to end: a serving trace through the real shard/group machinery
// must serve bit-identical tokens under --no-simd and auto-dispatch
// (the SimEngine token function folds a simd::dot fingerprint into
// every token, so kernel divergence would change the stream).
// ---------------------------------------------------------------------

#[test]
fn serving_trace_tokens_identical_with_and_without_simd() {
    let _g = lock();
    let sim_cfg = SimConfig { batch: 2, ..Default::default() };
    let prompts: Vec<Vec<i32>> =
        (0..10).map(|i| vec![3, 40 + i, 80 + 3 * i, 9]).collect();

    let run = |scalar_mode: bool| {
        with_mode(scalar_mode, || {
            // Direct engine pass (single-threaded determinism check).
            let mut eng = SimEngine::new(sim_cfg);
            for (i, p) in prompts.iter().enumerate() {
                DecodeEngine::submit(&mut eng, Request::new(i as u64, p.clone(), 24));
            }
            let mut direct: Vec<(u64, Vec<i32>)> = eng
                .run_to_completion()
                .unwrap()
                .into_iter()
                .map(|c| (c.id, c.generated))
                .collect();
            direct.sort();

            // Group pass: 2 shards, real router/steal/completion fan-in.
            let gcfg = GroupConfig { shards: 2, affinity_slack: 1,
                                     queue_depth: 16, ..Default::default() };
            let mut group: EngineGroup<SimEngine> =
                EngineGroup::with_config(gcfg, move |_| Ok(SimEngine::new(sim_cfg)))
                    .unwrap();
            for (i, p) in prompts.iter().enumerate() {
                let out = group
                    .submit(Request::new(100 + i as u64, p.clone(), 24))
                    .unwrap();
                assert!(matches!(out, SubmitOutcome::Routed(_)),
                        "queue_depth 16 must admit the whole trace");
            }
            let mut grouped: Vec<(u64, Vec<i32>)> = Vec::new();
            while grouped.len() < prompts.len() {
                if let Some(c) =
                    group.poll(std::time::Duration::from_millis(200)).unwrap()
                {
                    grouped.push((c.id, c.generated));
                }
            }
            group.shutdown().unwrap();
            grouped.sort();
            (direct, grouped)
        })
    };

    let (scalar_direct, scalar_grouped) = run(true);
    let (auto_direct, auto_grouped) = run(false);
    assert_eq!(scalar_direct, auto_direct,
               "served tokens diverged between --no-simd and auto dispatch");
    assert_eq!(scalar_grouped, auto_grouped,
               "sharded serving tokens diverged between dispatch modes");
    // Shard placement must not matter either (same content, offset ids).
    for ((da, dg), (ga, gg)) in scalar_direct.iter().zip(&scalar_grouped) {
        assert_eq!(da + 100, *ga);
        assert_eq!(dg, gg, "group output differs from direct engine");
    }
}

#[test]
fn expected_generation_is_dispatch_invariant() {
    let _g = lock();
    let cfg = SimConfig::default();
    for i in 0..12 {
        let prompt = vec![1 + i, 7, 2 * i];
        let s = with_mode(true, || SimEngine::expected_generation(&cfg, &prompt, 20));
        let v = with_mode(false, || SimEngine::expected_generation(&cfg, &prompt, 20));
        assert_eq!(s, v, "prompt {prompt:?}");
    }
}
