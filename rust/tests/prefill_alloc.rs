//! Allocation-regression test for the prefill staging path (same
//! counting-allocator harness as `benches/decode_hot_path.rs`, same
//! synthetic-replica approach as `tests/hot_path_parity.rs`).
//!
//! The seed engine allocated five buffers per `admit_and_prefill` call:
//! the padded `ids [b, s]` / `seq_len [b]` batch tensors and the
//! per-token `krow`/`vrow`/`prow` scatter rows. All five now live in the
//! engine-owned `StagingArena` (`PrefillStaging`), so the staging + row
//! scatter work of a steady-state admission — including the paged-cache
//! appends, whose page tables and pool free-list retain capacity across
//! release/re-admit — performs **zero** heap allocations after warm-up.
//!
//! (Per-request cache *state* — fresh `KcompCache`/`QuestMeta` per
//! admitted sequence — is intentionally out of scope: it is new state
//! per request, not staging; see PERF.md.)
//!
//! This file holds exactly one test so no concurrent test thread can
//! allocate while the counter is armed.

use seerattn::coordinator::StagingArena;
use seerattn::kvcache::{PagedKvPool, SeqKv};
use seerattn::util::alloc_count::{count_allocs, CountingAlloc};
use seerattn::util::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// Fixture geometry (mirrors one engine prefill batch).
const B: usize = 4;
const S: usize = 64;
const HKV: usize = 2;
const DH: usize = 4;
const LAYERS: usize = 2;
const BS: usize = 4;

struct Fixture {
    /// Fake prefill executable outputs, layout [L, B, Hkv, S, dh] (one
    /// array standing in for each of k_rope / v / k_pre).
    kr: Vec<f32>,
    vv: Vec<f32>,
    kp: Vec<f32>,
    /// Two admission waves with different prompt lengths (dirty extents
    /// must churn between acquires).
    prompt_sets: [Vec<Vec<i32>>; 2],
}

fn fixture(seed: u64) -> Fixture {
    let mut rng = Rng::new(seed);
    let n = LAYERS * B * HKV * S * DH;
    let mut gen = |_: usize| (0..n).map(|_| rng.normal() as f32).collect::<Vec<_>>();
    let kr = gen(0);
    let vv = gen(1);
    let kp = gen(2);
    let mut prompts = |lo: usize| {
        (0..B)
            .map(|i| (0..lo + 7 * i % 40 + 5).map(|t| (t % 97) as i32).collect())
            .collect::<Vec<Vec<i32>>>()
    };
    let prompt_sets = [prompts(9), prompts(23)];
    Fixture { kr, vv, kp, prompt_sets }
}

/// One synthetic `admit_and_prefill`: stage the padded batch through the
/// arena, then scatter the per-token rows into the paged KV caches —
/// exactly the host-side work the engine's prefill performs around the
/// device call.
fn prefill_step(fx: &Fixture, wave: usize, arena: &mut StagingArena,
                pool: &mut PagedKvPool, kv: &mut [Vec<SeqKv>]) {
    let prompts = &fx.prompt_sets[wave];
    // Steady-state re-admission: finished sequences release their pages
    // (page tables and the pool free list retain capacity).
    for per_layer in kv.iter_mut() {
        for seq in per_layer.iter_mut() {
            seq.release(pool);
        }
    }
    let set = arena.prefill(B, S, HKV * DH);
    {
        let (ids, seq_len, dirty) = set.ids_mut();
        for (i, p) in prompts.iter().enumerate() {
            ids[i * S..i * S + p.len()].copy_from_slice(p);
            seq_len[i] = p.len() as i32;
            dirty[i] = p.len();
        }
    }
    let idx = |l: usize, bi: usize, h: usize, t: usize| {
        (((l * B + bi) * HKV + h) * S + t) * DH
    };
    let (krow, vrow, prow) = set.rows_mut();
    for (i, p) in prompts.iter().enumerate() {
        for t in 0..p.len() {
            for l in 0..LAYERS {
                for h in 0..HKV {
                    let o = idx(l, i, h, t);
                    krow[h * DH..(h + 1) * DH].copy_from_slice(&fx.kr[o..o + DH]);
                    vrow[h * DH..(h + 1) * DH].copy_from_slice(&fx.vv[o..o + DH]);
                    prow[h * DH..(h + 1) * DH].copy_from_slice(&fx.kp[o..o + DH]);
                }
                kv[i][l].append(pool, krow, vrow).unwrap();
            }
        }
    }
}

#[test]
fn prefill_staging_zero_steady_state_allocations() {
    let fx = fixture(19);
    let mut arena = StagingArena::new();
    let pages_per_seq = S / BS + 1;
    let mut pool = PagedKvPool::new(B * LAYERS * pages_per_seq, HKV, DH, BS);
    let mut kv: Vec<Vec<SeqKv>> =
        (0..B).map(|_| (0..LAYERS).map(|_| SeqKv::new()).collect()).collect();

    // Warm-up: create the prefill set, grow page tables to max extent.
    for wave in [0, 1, 0, 1] {
        prefill_step(&fx, wave, &mut arena, &mut pool, &mut kv);
    }
    assert_eq!(arena.allocations(), 1, "one prefill staging set ever");

    // Steady state: admissions alternate between prompt-length waves;
    // the staging path must not touch the heap at all.
    let allocs = count_allocs(|| {
        for step in 0..20 {
            prefill_step(&fx, step % 2, &mut arena, &mut pool, &mut kv);
        }
    });
    assert_eq!(allocs, 0,
               "steady-state admit_and_prefill staging allocated {allocs} times");
    assert_eq!(arena.allocations(), 1);

    // Sanity: the caches really were refilled (not skipped).
    for per_layer in &kv {
        for seq in per_layer {
            assert!(seq.len > 0);
            assert_eq!(seq.n_blocks(), seq.len.div_ceil(BS));
        }
    }
    // And all pages flow back on release (no leaks across waves).
    for per_layer in kv.iter_mut() {
        for seq in per_layer.iter_mut() {
            seq.release(&mut pool);
        }
    }
    assert_eq!(pool.free_pages(), pool.capacity());
}