//! Hot-path parity: the optimized decode-path implementations (partial
//! top-k over a scratch buffer, `*_into` scoring, arena-based staged
//! gather with dirty-extent clearing) must produce **bit-identical**
//! results to the seed implementations, across random steps that reuse
//! the same buffers. Pure host — runs under the default feature set.

use seerattn::coordinator::StagingArena;
use seerattn::kvcache::{PagedKvPool, SeqKv};
use seerattn::sparse::policy::{select_budget, select_budget_into,
                               select_threshold, select_threshold_into,
                               select_top_p, select_top_p_into, SelKind,
                               SelectionBuf};
use seerattn::sparse::topk::{top_p_indices, topk_indices, TopkScratch};
use seerattn::util::rng::Rng;

// ---------------------------------------------------------------------
// Seed reference implementations (full sort, fresh allocations) — kept
// here verbatim so the optimized paths are checked against the original
// behaviour, not against themselves.
// ---------------------------------------------------------------------

fn seed_topk(scores: &[f32], k: usize) -> Vec<i32> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut picked: Vec<i32> = order[..k].iter().map(|&i| i as i32).collect();
    picked.sort_unstable();
    picked
}

fn seed_top_p(probs: &[f32], p: f32) -> Vec<i32> {
    if probs.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| {
        probs[b]
            .partial_cmp(&probs[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mass = 0.0f32;
    let mut picked: Vec<i32> = Vec::new();
    for &i in &order {
        picked.push(i as i32);
        mass += probs[i];
        if mass >= p {
            break;
        }
    }
    picked.sort_unstable();
    picked
}

#[test]
fn partial_select_topk_bit_identical_to_seed_sort() {
    let mut rng = Rng::new(101);
    let mut scratch = TopkScratch::new();
    let mut out = Vec::new();
    for _ in 0..300 {
        let n = rng.range(1, 80);
        let k = rng.range(0, n + 3);
        // Include heavy ties to stress the tie-break.
        let scores: Vec<f32> = (0..n)
            .map(|_| if rng.bool(0.3) { 0.5 } else { rng.normal() as f32 })
            .collect();
        let expect = seed_topk(&scores, k);
        assert_eq!(topk_indices(&scores, k), expect);
        scratch.topk_into(&scores, k, &mut out);
        assert_eq!(out, expect);
    }
}

#[test]
fn partial_select_top_p_bit_identical_to_seed_sort() {
    let mut rng = Rng::new(102);
    let mut scratch = TopkScratch::new();
    let mut out = Vec::new();
    for _ in 0..300 {
        let n = rng.range(1, 80);
        let mut probs: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-6).collect();
        let total: f32 = probs.iter().sum();
        for x in &mut probs {
            *x /= total;
        }
        let p = if rng.bool(0.1) { 1.5 } else { rng.f32() };
        let expect = seed_top_p(&probs, p);
        assert_eq!(top_p_indices(&probs, p), expect, "p={p}");
        scratch.top_p_into(&probs, p, &mut out);
        assert_eq!(out, expect, "p={p}");
    }
}

#[test]
fn select_into_reused_buffers_match_seed_selection() {
    let mut rng = Rng::new(103);
    let mut buf = SelectionBuf::new();
    let mut topk = TopkScratch::new();
    for _ in 0..200 {
        let heads = rng.range(1, 6);
        let n = rng.range(0, 32);
        let scores: Vec<Vec<f32>> = (0..heads)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let partial = if rng.bool(0.5) { Some(n as i32) } else { None };
        let b = rng.range(1, 10);
        select_budget_into(&scores, b, partial, &mut topk, &mut buf);
        assert_eq!(buf.rows(), &select_budget(&scores, b, partial)[..]);
        let t = rng.f32();
        select_threshold_into(&scores, t, partial, &mut buf);
        assert_eq!(buf.rows(), &select_threshold(&scores, t, partial)[..]);
        let p = rng.f32();
        select_top_p_into(&scores, p, partial, &mut topk, &mut buf);
        assert_eq!(buf.rows(), &select_top_p(&scores, p, partial)[..]);
    }
}

// ---------------------------------------------------------------------
// Arena gather vs the seed's fresh-allocation gather.
// ---------------------------------------------------------------------

const BS: usize = 4;
const HKV: usize = 2;
const H_ALL: usize = 4;
const G: usize = H_ALL / HKV;
const DH: usize = 3;

struct World {
    pool: PagedKvPool,
    seqs: Vec<SeqKv>,
    rng: Rng,
}

impl World {
    fn new(seed: u64, batch: usize) -> World {
        let mut w = World {
            pool: PagedKvPool::new(batch * 20, HKV, DH, BS),
            seqs: (0..batch).map(|_| SeqKv::new()).collect(),
            rng: Rng::new(seed),
        };
        for i in 0..batch {
            let t = w.rng.range(1, 28);
            w.grow(i, t);
        }
        w
    }

    fn grow(&mut self, i: usize, tokens: usize) {
        for _ in 0..tokens {
            let k: Vec<f32> = (0..HKV * DH).map(|_| self.rng.normal() as f32).collect();
            let v: Vec<f32> = (0..HKV * DH).map(|_| self.rng.normal() as f32).collect();
            self.seqs[i].append(&mut self.pool, &k, &v).unwrap();
        }
    }

    /// Random ascending block selection that always includes the partial
    /// last block (the §3.2 invariant the engine enforces).
    fn random_rows(&mut self, i: usize, n_rows: usize) -> Vec<Vec<i32>> {
        let nblk = self.seqs[i].n_blocks();
        (0..n_rows)
            .map(|_| {
                let take = self.rng.range(1, nblk + 1);
                let mut picked = self.rng.sample_distinct(nblk, take);
                let last = nblk - 1;
                if !picked.contains(&last) {
                    picked.push(last);
                }
                picked.sort_unstable();
                picked.into_iter().map(|b| b as i32).collect()
            })
            .collect()
    }
}

/// The gather write pattern both implementations share.
fn write_gather(pool: &PagedKvPool, seqs: &[SeqKv], sels: &[(SelKind, Vec<Vec<i32>>)],
                per_head: bool, t_cap: usize, k: &mut [f32], v: &mut [f32],
                mask: &mut [f32], dirty: Option<&mut [usize]>) {
    let heads = if per_head { H_ALL } else { HKV };
    let mut dirty = dirty;
    for (i, seq) in seqs.iter().enumerate() {
        let (kind, rows) = &sels[i];
        for hr in 0..heads {
            let row: &[i32] = match kind {
                SelKind::Shared if per_head => &rows[hr / G],
                SelKind::Shared | SelKind::PerHead => &rows[hr],
                SelKind::Dense => unreachable!(),
            };
            let kv_head = if per_head { hr / G } else { hr };
            let mut cursor = 0usize;
            for &j in row {
                let n = seq.tokens_in_block(j as usize, BS);
                let pg = seq.pages[j as usize];
                let off = ((i * heads + hr) * t_cap + cursor) * DH;
                pool.gather_block(pg, kv_head, n, &mut k[off..off + n * DH],
                                  &mut v[off..off + n * DH]);
                let moff = (i * heads + hr) * t_cap + cursor;
                mask[moff..moff + n].fill(1.0);
                cursor += n;
            }
            if let Some(d) = dirty.as_deref_mut() {
                d[i * heads + hr] = cursor;
            }
        }
    }
}

#[test]
fn arena_gather_bit_identical_to_fresh_alloc_gather() {
    let batch = 2;
    let mut w = World::new(104, batch);
    let mut arena = StagingArena::new();
    for step in 0..40 {
        // Alternate Shared / PerHead / mixed batches and staging caps so
        // the same arena sets are re-dirtied with different shapes.
        let per_head = step % 3 == 1 || step % 3 == 2;
        let mixed = step % 3 == 2;
        let t_cap = if step % 2 == 0 { 8 * BS } else { 16 * BS };
        let heads = if per_head { H_ALL } else { HKV };
        let sels: Vec<(SelKind, Vec<Vec<i32>>)> = (0..batch)
            .map(|i| {
                if per_head && !(mixed && i == 0) {
                    (SelKind::PerHead, w.random_rows(i, H_ALL))
                } else {
                    (SelKind::Shared, w.random_rows(i, HKV))
                }
            })
            .collect();

        // Reference: fresh zero-filled buffers (the seed behaviour).
        let mut k_ref = vec![0f32; batch * heads * t_cap * DH];
        let mut v_ref = vec![0f32; batch * heads * t_cap * DH];
        let mut m_ref = vec![0f32; batch * heads * t_cap];
        write_gather(&w.pool, &w.seqs, &sels, per_head, t_cap, &mut k_ref,
                     &mut v_ref, &mut m_ref, None);

        // Optimized: dirty-cleared persistent arena set. Comparing the
        // *entire* buffers against the zero-seeded reference catches any
        // stale bytes a buggy dirty-extent reset would leave behind.
        let set = arena.sparse(batch, heads, t_cap, DH);
        {
            let (k, v, m, dirty) = set.parts_mut();
            write_gather(&w.pool, &w.seqs, &sels, per_head, t_cap, k, v, m,
                         Some(dirty));
        }
        assert_eq!(set.k.as_f32().unwrap(), &k_ref[..], "k step={step}");
        assert_eq!(set.v.as_f32().unwrap(), &v_ref[..], "v step={step}");
        assert_eq!(set.mask.as_f32().unwrap(), &m_ref[..], "mask step={step}");

        // Contexts drift between steps (incl. across block boundaries) so
        // partial last blocks move around. Lengths stay <= 8 blocks = 32
        // tokens so every row fits the smallest staging cap.
        for i in 0..batch {
            if w.seqs[i].len < 27 {
                let t = w.rng.range(0, 4);
                w.grow(i, t);
            }
        }
    }
    // Two t_caps x two head counts = at most 4 sparse sets ever created.
    assert!(arena.allocations() <= 4, "allocations {}", arena.allocations());
}

#[test]
fn arena_dense_gather_matches_fresh_alloc() {
    let batch = 2;
    let s = 32;
    let mut w = World::new(105, batch);
    let mut arena = StagingArena::new();
    for step in 0..20 {
        let mut k_ref = vec![0f32; batch * HKV * s * DH];
        let mut v_ref = vec![0f32; batch * HKV * s * DH];
        let mut sl_ref = vec![0i32; batch];
        for (i, seq) in w.seqs.iter().enumerate() {
            sl_ref[i] = seq.len as i32;
            for h in 0..HKV {
                for (blk, &pg) in seq.pages.iter().enumerate() {
                    let n = seq.tokens_in_block(blk, BS);
                    let off = ((i * HKV + h) * s + blk * BS) * DH;
                    w.pool.gather_block(pg, h, n, &mut k_ref[off..off + n * DH],
                                        &mut v_ref[off..off + n * DH]);
                }
            }
        }
        let set = arena.dense(batch, HKV, s, DH);
        {
            let (k, v, sl, dirty) = set.parts_mut();
            for (i, seq) in w.seqs.iter().enumerate() {
                sl[i] = seq.len as i32;
                for h in 0..HKV {
                    for (blk, &pg) in seq.pages.iter().enumerate() {
                        let n = seq.tokens_in_block(blk, BS);
                        let off = ((i * HKV + h) * s + blk * BS) * DH;
                        w.pool.gather_block(pg, h, n, &mut k[off..off + n * DH],
                                            &mut v[off..off + n * DH]);
                    }
                    dirty[i * HKV + h] = seq.len;
                }
            }
        }
        assert_eq!(set.k.as_f32().unwrap(), &k_ref[..], "k step={step}");
        assert_eq!(set.v.as_f32().unwrap(), &v_ref[..], "v step={step}");
        assert_eq!(set.seq_len.as_i32().unwrap(), &sl_ref[..], "sl step={step}");
        for i in 0..batch {
            if w.seqs[i].len + 5 < s {
                let t = w.rng.range(0, 5);
                w.grow(i, t);
            }
        }
    }
    assert_eq!(arena.allocations(), 1);
}
