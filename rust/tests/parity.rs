//! Cross-language parity: the Rust-side gate math (K compression, gate
//! scores, oracle ground truth) must agree with the JAX reference, via
//! the golden values in `artifacts/fixtures.json`.

use seerattn::gate;
use seerattn::harness;
use seerattn::model::ModelConfig;
use seerattn::util::json::Json;

fn load() -> Option<(ModelConfig, Json)> {
    if !harness::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let fx = Json::parse_file(&harness::artifacts_dir().join("fixtures.json")).unwrap();
    let cfg = ModelConfig::from_json(fx.get("config").unwrap()).unwrap();
    Some((cfg, fx))
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn kcomp_matches_jax() {
    let Some((cfg, fx)) = load() else { return };
    let kc = fx.get("kcomp").unwrap();
    let k_pre = kc.get("k_pre").unwrap().as_f32_vec().unwrap();
    let wk = kc.get("wk_gate").unwrap().as_f32_vec().unwrap();
    let expect = kc.get("expected_kc").unwrap().as_f32_vec().unwrap();
    let bs = cfg.block_size;
    let (hkv, dh, dg) = (cfg.n_kv_heads, cfg.head_dim, cfg.d_gate);
    // fixture layout: k_pre [1, Hkv, 2*bs, dh]; expected [1, Hkv, 2, dg]
    let mut got = vec![0f32; hkv * 2 * dg];
    for blk in 0..2 {
        // extract [Hkv, bs, dh] block `blk`
        let mut block = vec![0f32; hkv * bs * dh];
        for h in 0..hkv {
            for t in 0..bs {
                let src = (h * 2 * bs + blk * bs + t) * dh;
                let dst = (h * bs + t) * dh;
                block[dst..dst + dh].copy_from_slice(&k_pre[src..src + dh]);
            }
        }
        let entry = gate::kcomp_entry(&cfg, &wk, &block, bs, (blk * bs) as i64);
        for h in 0..hkv {
            let dst = (h * 2 + blk) * dg;
            got[dst..dst + dg].copy_from_slice(&entry[h * dg..(h + 1) * dg]);
        }
    }
    close(&got, &expect, 2e-4, "kcomp");
}

#[test]
fn gate_scores_match_jax() {
    let Some((cfg, fx)) = load() else { return };
    let gq = fx.get("gate_query").unwrap();
    let qg = gq.get("expected_qg").unwrap().as_f32_vec().unwrap();
    let expect = gq.get("expected_scores").unwrap().as_f32_vec().unwrap();
    let kcfx = fx.get("kcomp").unwrap();
    let kc = kcfx.get("expected_kc").unwrap().as_f32_vec().unwrap();
    // kc layout [Hkv, 2, dg]; gate_scores wants [Hkv, entries, dg].
    let got = gate::gate_scores(&cfg, &qg, &kc, 2, 2);
    close(&got, &expect, 2e-4, "gate_scores");
}

#[test]
fn oracle_gt_matches_jax() {
    let Some((cfg, fx)) = load() else { return };
    let orc = fx.get("oracle").unwrap();
    let q = orc.get("q_rope").unwrap().as_f32_vec().unwrap();
    let k = orc.get("k_rope").unwrap().as_f32_vec().unwrap();
    let len = orc.get("seq_len").unwrap().as_usize().unwrap();
    let expect = orc.get("expected_gt").unwrap().as_f32_vec().unwrap();
    let bs = cfg.block_size;
    let s_total = 4 * bs;
    let dh = cfg.head_dim;
    // k layout [1, Hkv, S, dh]
    let k_at = |h: usize, t: usize| -> *const f32 { k[(h * s_total + t) * dh..].as_ptr() };
    let got = gate::oracle_scores(&cfg, &q, &k_at, len, bs);
    // expected covers all 4 blocks; ours covers ceil(len/bs) blocks. The
    // fixture uses len = 4*bs-3 -> same 4 blocks.
    close(&got, &expect, 2e-4, "oracle");
}

#[test]
fn manifest_and_config_consistency() {
    let Some((cfg, _fx)) = load() else { return };
    let rt = seerattn::runtime::Runtime::load(&harness::artifacts_dir()).unwrap();
    let mcfg = ModelConfig::from_json(&rt.manifest.model).unwrap();
    assert_eq!(cfg, mcfg, "fixtures vs manifest config");
    // Parameter layout covers the expected tensor count.
    assert_eq!(rt.manifest.params.len(), 2 + 8 * mcfg.n_layers + 1);
    assert_eq!(rt.manifest.gate_params.len(), 2 * mcfg.n_layers);
    // Every executable file exists on disk.
    for exe in rt.manifest.executables.values() {
        assert!(exe.file.exists(), "missing {:?}", exe.file);
    }
}
