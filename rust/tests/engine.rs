//! Engine integration tests (need `make artifacts` and the `pjrt`
//! feature; self-skip otherwise).
#![cfg(feature = "pjrt")]
//!
//! The key correctness property: with the budget set to the whole
//! context, every sparse policy must generate exactly the same tokens as
//! the dense baseline (greedy sampling is deterministic).

use std::rc::Rc;

use seerattn::coordinator::{Engine, EngineConfig, Request};
use seerattn::harness;
use seerattn::runtime::Runtime;
use seerattn::sparse::Policy;
use seerattn::util::rng::Rng;
use seerattn::workload::reasoning::{generate, TaskConfig};
use seerattn::workload::Vocab;

fn runtime() -> Option<Rc<Runtime>> {
    if !harness::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(Runtime::load(&harness::artifacts_dir()).unwrap()))
}

fn engine(rt: &Rc<Runtime>, ecfg: EngineConfig) -> Engine {
    harness::build_engine(rt, &harness::artifacts_dir(), ecfg).unwrap()
}

fn gen_tokens(eng: &mut Engine, prompts: &[Vec<i32>], max_new: usize) -> Vec<Vec<i32>> {
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(Request::new(i as u64, p.clone(), max_new));
    }
    let mut out = vec![Vec::new(); prompts.len()];
    for c in eng.run_to_completion().unwrap() {
        out[c.id as usize] = c.generated;
    }
    out
}

fn sample_prompts(n: usize) -> Vec<Vec<i32>> {
    let vocab = Vocab::default();
    let mut rng = Rng::new(99);
    (0..n)
        .map(|_| generate(&vocab, &TaskConfig { hops: 2, n_chains: 10 }, &mut rng).prompt)
        .collect()
}

#[test]
fn full_budget_policies_match_dense() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(3);
    let max_new = 12;
    let dense = gen_tokens(&mut engine(&rt, EngineConfig::default()), &prompts, max_new);
    // Budget >= max_seq selects every block.
    for policy in [
        Policy::Oracle { budget_tokens: 4096 },
        Policy::GateBudget { budget_tokens: 4096 },
        Policy::Quest { budget_tokens: 4096 },
    ] {
        let ecfg = EngineConfig { policy, ..Default::default() };
        let got = gen_tokens(&mut engine(&rt, ecfg), &prompts, max_new);
        assert_eq!(got, dense, "{policy:?} with full budget must equal dense");
    }
}

#[test]
fn threshold_zero_matches_dense() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let dense = gen_tokens(&mut engine(&rt, EngineConfig::default()), &prompts, 8);
    // Threshold below any softmax probability selects everything.
    let ecfg = EngineConfig {
        policy: Policy::GateThreshold { threshold: -1.0 },
        ..Default::default()
    };
    let got = gen_tokens(&mut engine(&rt, ecfg), &prompts, 8);
    assert_eq!(got, dense);
}

#[test]
fn continuous_batching_handles_more_requests_than_slots() {
    let Some(rt) = runtime() else { return };
    let mut eng = engine(&rt, EngineConfig {
        policy: Policy::GateBudget { budget_tokens: 128 },
        ..Default::default()
    });
    let n = eng.batch_size() + 3;
    let prompts = sample_prompts(n);
    let outs = gen_tokens(&mut eng, &prompts, 6);
    assert_eq!(outs.len(), n);
    for o in &outs {
        assert!(!o.is_empty(), "every request must generate");
    }
    // All pages returned to the pool.
    assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    assert_eq!(eng.metrics.requests_completed as usize, n);
}

#[test]
fn dense_first_layers_with_full_budget_matches_dense() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let dense = gen_tokens(&mut engine(&rt, EngineConfig::default()), &prompts, 8);
    let ecfg = EngineConfig {
        policy: Policy::GateBudget { budget_tokens: 4096 },
        dense_first_layers: 2,
        ..Default::default()
    };
    let got = gen_tokens(&mut engine(&rt, ecfg), &prompts, 8);
    assert_eq!(got, dense);
}

#[test]
fn block_sizes_agree_at_full_budget() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let dense = gen_tokens(&mut engine(&rt, EngineConfig::default()), &prompts, 8);
    for bs in [8usize, 32, 64] {
        let ecfg = EngineConfig {
            policy: Policy::Oracle { budget_tokens: 4096 },
            block_size: bs,
            ..Default::default()
        };
        let got = gen_tokens(&mut engine(&rt, ecfg), &prompts, 8);
        assert_eq!(got, dense, "block size {bs}");
    }
}

#[test]
fn sparse_budget_reduces_kv_traffic() {
    let Some(rt) = runtime() else { return };
    // Long contexts (3-hop task, ~290 tokens) so a 64-token budget bites.
    let vocab = Vocab::default();
    let mut prng = Rng::new(5);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|_| generate(&vocab, &TaskConfig::hard(), &mut prng).prompt)
        .collect();
    let mut eng = engine(&rt, EngineConfig {
        policy: Policy::GateBudget { budget_tokens: 64 },
        ..Default::default()
    });
    gen_tokens(&mut eng, &prompts, 16);
    let frac = eng.metrics.kv_touch_fraction();
    assert!(frac < 0.6, "budget 64 of ~450-token contexts must cut traffic, got {frac}");

    let mut dense_eng = engine(&rt, EngineConfig::default());
    gen_tokens(&mut dense_eng, &prompts, 16);
    assert!(dense_eng.metrics.kv_touch_fraction() > 0.99);
}

#[test]
fn recall_tracking_produces_values() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let mut eng = engine(&rt, EngineConfig {
        policy: Policy::GateBudget { budget_tokens: 128 },
        track_recall: true,
        ..Default::default()
    });
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(Request::new(i as u64, p.clone(), 8));
    }
    let comps = eng.run_to_completion().unwrap();
    for c in comps {
        let r = c.stats.mean_recall().expect("recall tracked");
        assert!((0.0..=1.0).contains(&r), "recall {r}");
        assert!(!c.stats.activated.is_empty(), "activation points recorded");
    }
}

#[test]
fn deterministic_across_runs() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let mk = || EngineConfig {
        policy: Policy::GateBudget { budget_tokens: 128 },
        seed: 7,
        temperature: 0.8,
        ..Default::default()
    };
    let a = gen_tokens(&mut engine(&rt, mk()), &prompts, 10);
    let b = gen_tokens(&mut engine(&rt, mk()), &prompts, 10);
    assert_eq!(a, b, "same seed => same sampled generation");
}

#[test]
fn trace_runner_serves_poisson_trace() {
    use seerattn::coordinator::scheduler::{Replay, TraceRunner};
    use seerattn::workload::trace::poisson_trace;
    let Some(rt) = runtime() else { return };
    let vocab = Vocab::default();
    let mut rng = Rng::new(1);
    let trace = poisson_trace(&vocab, &[TaskConfig { hops: 1, n_chains: 8 }],
                              10, 100.0, 6, &mut rng);
    let mut eng = engine(&rt, EngineConfig {
        policy: Policy::GateBudget { budget_tokens: 128 },
        ..Default::default()
    });
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let comps = runner.run(&mut eng, &trace).unwrap();
    assert_eq!(comps.len(), 10);
    let mut ids: Vec<u64> = comps.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    assert_eq!(eng.pool_free(), eng.pool_capacity());
}

#[test]
fn offload_accounting_dense_vs_sparse() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let mut fetched = Vec::new();
    for policy in [Policy::Dense, Policy::GateBudget { budget_tokens: 64 }] {
        let mut eng = engine(&rt, EngineConfig {
            policy,
            offload_fast_pages: 8,
            ..Default::default()
        });
        gen_tokens(&mut eng, &prompts, 8);
        let t = eng.offload.as_ref().unwrap();
        assert!(t.bytes_fetched > 0);
        fetched.push(t.bytes_fetched);
    }
    assert!(fetched[1] < fetched[0],
            "sparse selection must fetch fewer slow-tier bytes: {fetched:?}");
}

#[test]
fn top_p_full_mass_matches_dense_and_adapts() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(2);
    let dense = gen_tokens(&mut engine(&rt, EngineConfig::default()), &prompts, 8);
    // p = 1.0 selects every block with nonzero mass -> identical to dense.
    let got = gen_tokens(
        &mut engine(&rt, EngineConfig {
            policy: Policy::GateTopP { p: 1.0 },
            ..Default::default()
        }),
        &prompts,
        8,
    );
    assert_eq!(got, dense);
    // A small p must reduce KV traffic below dense.
    let mut eng = engine(&rt, EngineConfig {
        policy: Policy::GateTopP { p: 0.5 },
        ..Default::default()
    });
    gen_tokens(&mut eng, &prompts, 8);
    assert!(eng.metrics.kv_touch_fraction() < 1.0);
}

#[test]
fn chunked_prefill_matches_monolithic_token_streams() {
    let Some(rt) = runtime() else { return };
    let prompts = sample_prompts(3);
    let mono = gen_tokens(
        &mut engine(&rt, EngineConfig { prefill_chunk: 0, ..Default::default() }),
        &prompts,
        8,
    );
    // One gate block per chunk: every admission spans multiple steps,
    // with decode interleaved — the KV state and sampled streams must
    // still be bit-identical to the monolithic path.
    let chunked = gen_tokens(
        &mut engine(&rt, EngineConfig { prefill_chunk: 16, ..Default::default() }),
        &prompts,
        8,
    );
    assert_eq!(chunked, mono,
               "chunked prefill must be bit-identical to monolithic");
}

#[test]
fn cancel_mid_prefill_frees_pages_without_streaming() {
    use seerattn::coordinator::DecodeEngine;
    let Some(rt) = runtime() else { return };
    let mut eng = engine(&rt, EngineConfig { prefill_chunk: 16,
                                             ..Default::default() });
    let capacity = eng.pool_capacity();
    // 48 prompt tokens over a 16-token chunk: after one step the slot is
    // half-prefilled — pages reserved, nothing sampled yet.
    let prompt: Vec<i32> = (0..48).map(|t| 4 + (t % 80)).collect();
    eng.submit(Request::new(9, prompt, 8));
    let first = DecodeEngine::step(&mut eng).unwrap();
    assert!(first.is_empty(), "half-prefilled slot must not complete");
    assert!(eng.pool_free() < capacity, "admitted slot holds its pages");
    assert!(DecodeEngine::cancel(&mut eng, 9));
    let comps = DecodeEngine::step(&mut eng).unwrap();
    assert_eq!(comps.len(), 1, "cancel mid-prefill must finish the request");
    assert_eq!(comps[0].stop, seerattn::coordinator::request::StopReason::Cancelled);
    assert!(comps[0].generated.is_empty(), "no tokens were ever streamed");
    assert_eq!(eng.pool_free(), capacity,
               "mid-prefill cancellation leaked pages");
}
