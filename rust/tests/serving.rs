//! End-to-end serving golden tests (pure host, default feature set).
//!
//! These drive the *production* serving code paths — `EngineGroup` shard
//! threads + bounded router + work stealing + completion fan-in,
//! `TraceRunner` replay, and the epoll-reactor JSON-lines TCP server —
//! with the deterministic `SimEngine` backend, pinning the properties
//! the serving layer promises:
//!
//!  1. N-shard `TraceRunner` output is per-request identical to
//!     single-engine output on a seeded mixed Poisson trace.
//!  2. The reactor front-end serves that same trace over real sockets
//!     with per-request output identical to the single-engine blocking
//!     baseline (the ISSUE 3 acceptance criterion).
//!  3. Virtual-time replay is deterministic under a fixed rng seed.
//!  4. The failure surfaces behave: idle/slow-loris connections are
//!     evicted while in-flight work completes, over-cap connections get
//!     structured rejections, and bursts beyond `queue_depth` get
//!     structured `overloaded` replies — no hangs, no panics.
//!  5. The persistent-pool parallel gather is bit-identical to the
//!     serial gather over the arena's disjoint dirty-extent rows.
//!  6. The streaming lifecycle (ISSUE 4): a `{"stream": true}` request's
//!     concatenated delta frames are byte-identical to the non-streaming
//!     reply for the same prompt, over real sockets with adversarial
//!     frame segmentation; a client disconnect mid-decode *cancels* the
//!     decode at its shard and releases its KV pages; a per-request
//!     deadline stops a decode with `"stop": "deadline"` and a partial
//!     generation.
//!  7. Memory-planned admission + priority preemption (ISSUE 6): under
//!     2x page oversubscription and seeded fault injection, no request
//!     is ever lost or duplicated, preempted-then-resumed requests stay
//!     bit-identical to the unconstrained token function, deferred
//!     submissions carry retry hints the trace runner honours with
//!     backoff, and cancel/disconnect storms leave every shard's page
//!     pool gauge at full capacity.
//!  8. Chunked prefill (ISSUE 7): interleaving admission with decode
//!     changes nothing a client can observe — on a long-prompt +
//!     short-decode mix, a 4-shard group's per-request output and a
//!     single engine's completion order are bit-identical between
//!     chunked and monolithic prefill under virtual replay.
//!  9. Content-addressed prefix cache (ISSUE 8): requests sharing a
//!     block-aligned prompt head on a 4-shard group prefill the head
//!     exactly once (prefix-affinity routing + sticky placement keep
//!     them together) with streams bit-identical to a cold cache; the
//!     bit-identity holds under the seeded chaos fault matrix; and a
//!     cancel storm on half-prefilled shared-prefix slots leaks neither
//!     pages nor cache pins — the full pool is re-admittable and the
//!     gauge returns to capacity.
//! 10. Shard supervision (ISSUE 10): a shard that panics mid-stream is
//!     respawned and its in-flight requests are replayed from the
//!     tokens the router already observed — the client's delta stream
//!     stays gapless and bit-identical across the crash; the seeded
//!     chaos matrix still loses nothing with a `Panic` fault in the
//!     mix; an admission-starved trace entry gives up after a bounded
//!     retry streak with a structured `resource_exhausted` outcome
//!     instead of livelocking; and SIGTERM drains the server
//!     gracefully — in-flight completes, idle connections get a
//!     goodbye, new work is refused, and serve() returns cleanly.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use seerattn::coordinator::request::StopReason;
use seerattn::coordinator::scheduler::{Replay, TraceRunner};
use seerattn::coordinator::server;
use seerattn::coordinator::{Completion, EngineGroup, Fault, FaultSchedule,
                            GroupConfig, Request, ServeConfig, SimConfig,
                            SimEngine, SubmitOutcome};
use seerattn::util::json::Json;
use seerattn::util::rng::Rng;
use seerattn::workload::trace::{poisson_trace, TracedRequest};
use seerattn::workload::{Episode, TaskConfig, Vocab};

fn mixed_trace(n: usize, seed: u64) -> Vec<TracedRequest> {
    let vocab = Vocab::default();
    let mixture = [TaskConfig::easy(), TaskConfig::hard()];
    let mut rng = Rng::new(seed);
    poisson_trace(&vocab, &mixture, n, 200.0, 24, &mut rng)
}

fn sim_group(shards: usize) -> EngineGroup<SimEngine> {
    EngineGroup::new(shards, |_| Ok(SimEngine::new(SimConfig::default()))).unwrap()
}

/// Key completions by request id for order-independent comparison.
fn by_id(comps: Vec<Completion>) -> BTreeMap<u64, (usize, Vec<i32>, StopReason)> {
    let n = comps.len();
    let map: BTreeMap<_, _> = comps
        .into_iter()
        .map(|c| (c.id, (c.prompt_len, c.generated, c.stop)))
        .collect();
    assert_eq!(map.len(), n, "duplicate completion ids");
    map
}

fn request_line(id: usize, prompt: &[i32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"id\": {id}, \"prompt\": [{}], \"max_new\": {max_new}}}",
            toks.join(", "))
}

// ---------------------------------------------------------------------
// 1-shard vs N-shard parity.
// ---------------------------------------------------------------------

#[test]
fn four_shards_match_single_engine_per_request() {
    let trace = mixed_trace(48, 7);
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };

    // Baseline: one engine on the caller's thread.
    let mut single = SimEngine::new(SimConfig::default());
    let base = by_id(runner.run(&mut single, &trace).unwrap());
    assert_eq!(base.len(), 48);

    for shards in [1usize, 4] {
        let mut group = sim_group(shards);
        let comps = by_id(runner.run_group(&mut group, &trace).unwrap());
        assert_eq!(comps.len(), base.len(), "{shards} shards: completion count");
        for (id, want) in &base {
            let got = comps.get(id).expect("missing id");
            assert_eq!(got, want, "{shards} shards: request {id} diverged");
        }
        let gm = group.shutdown().unwrap();
        assert_eq!(gm.fleet().requests_completed, 48);
        if shards == 4 {
            // The Poisson mix must actually have exercised every shard.
            assert!(gm.shards.iter().all(|m| m.requests_completed > 0),
                    "a shard sat idle: {:?}",
                    gm.shards.iter().map(|m| m.requests_completed).collect::<Vec<_>>());
        }
    }
}

#[test]
fn real_time_replay_matches_virtual_per_request() {
    // Short trace at a high rate so the real-time run stays fast.
    let trace = mixed_trace(8, 11);
    let virt = {
        let mut group = sim_group(2);
        let out = by_id(TraceRunner { replay: Replay::Virtual,
                                      ..Default::default() }
            .run_group(&mut group, &trace)
            .unwrap());
        group.shutdown().unwrap();
        out
    };
    let real = {
        let mut group = sim_group(2);
        let out = by_id(TraceRunner { replay: Replay::RealTime,
                                      ..Default::default() }
            .run_group(&mut group, &trace)
            .unwrap());
        group.shutdown().unwrap();
        out
    };
    assert_eq!(virt, real, "replay mode must not change per-request output");
}

// ---------------------------------------------------------------------
// Reactor front-end vs the single-engine blocking baseline (the
// acceptance criterion): a seeded 4-shard mixed Poisson trace served
// over real sockets, multiple pipelined connections, arrivals honoured.
// ---------------------------------------------------------------------

#[test]
fn reactor_front_end_matches_blocking_baseline_on_poisson_trace() {
    let trace = mixed_trace(48, 7);
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let mut single = SimEngine::new(SimConfig::default());
    let base = by_id(runner.run(&mut single, &trace).unwrap());

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group = sim_group(4);
    let cfg = ServeConfig { limit: Some(trace.len()), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // Three pipelined client connections, requests fanned round-robin in
    // arrival order, arrival times honoured against one shared clock.
    const CLIENTS: usize = 3;
    let mut conns: Vec<TcpStream> = (0..CLIENTS)
        .map(|_| TcpStream::connect(addr).unwrap())
        .collect();
    let mut sent: Vec<usize> = vec![0; CLIENTS];
    let t0 = Instant::now();
    for (i, t) in trace.iter().enumerate() {
        let due = Duration::from_secs_f64(t.arrival_s);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let c = i % CLIENTS;
        writeln!(conns[c], "{}", request_line(i, &t.episode.prompt, t.max_new))
            .unwrap();
        sent[c] += 1;
    }
    for c in &mut conns {
        c.flush().unwrap();
    }

    let mut got: BTreeMap<u64, (Vec<i32>, String)> = BTreeMap::new();
    for (c, conn) in conns.into_iter().enumerate() {
        let mut reader = BufReader::new(conn);
        for _ in 0..sent[c] {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad {line:?}"));
            assert!(j.get("error").is_err(), "unexpected error reply {line:?}");
            let id = j.get("id").unwrap().as_i64().unwrap() as u64;
            let generated: Vec<i32> = j
                .get("generated")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_i64().unwrap() as i32)
                .collect();
            let stop = j.get("stop").unwrap().as_str().unwrap().to_string();
            assert!(got.insert(id, (generated, stop)).is_none(),
                    "duplicate reply for {id}");
        }
    }
    srv.join().unwrap();

    assert_eq!(got.len(), base.len());
    for (id, (_plen, want_gen, want_stop)) in &base {
        let (gen, stop) = got.get(id).expect("missing reply");
        assert_eq!(gen, want_gen, "request {id} diverged from blocking baseline");
        assert_eq!(stop, want_stop.as_str(), "request {id} stop reason");
    }
}

// ---------------------------------------------------------------------
// Virtual-replay determinism under a fixed seed.
// ---------------------------------------------------------------------

#[test]
fn virtual_replay_is_deterministic_under_fixed_seed() {
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let mut outputs = Vec::new();
    for _ in 0..2 {
        // Regenerate the trace from the same seed each time: trace
        // generation + replay + engines must all be deterministic.
        let trace = mixed_trace(32, 23);
        let mut group = sim_group(3);
        outputs.push(by_id(runner.run_group(&mut group, &trace).unwrap()));
        group.shutdown().unwrap();
    }
    assert_eq!(outputs[0], outputs[1]);
    // And the generations really are the sim's pure function of the
    // request content.
    let trace = mixed_trace(32, 23);
    let cfg = SimConfig::default();
    for (id, (plen, generated, stop)) in &outputs[0] {
        let t = &trace[*id as usize];
        assert_eq!(*plen, t.episode.prompt.len());
        let (want, want_stop) =
            SimEngine::expected_generation(&cfg, &t.episode.prompt, t.max_new);
        assert_eq!(generated, &want, "id {id}");
        assert_eq!(stop, &want_stop, "id {id}");
    }
}

// ---------------------------------------------------------------------
// JSON-lines protocol over a real socket.
// ---------------------------------------------------------------------

#[test]
fn tcp_server_round_trips_pipelined_requests() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let n_requests = 6usize;
    let group = sim_group(2);
    let cfg = ServeConfig { limit: Some(n_requests), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| vec![1, 40 + i as i32, 41 + i as i32, 3])
        .collect();
    let mut conn = TcpStream::connect(addr).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        // Client ids deliberately offset from the server's internal ones.
        writeln!(conn, "{}", request_line(100 + i, p, 10)).unwrap();
    }
    conn.flush().unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let cfg = SimConfig::default();
    let mut seen = BTreeMap::new();
    for _ in 0..n_requests {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        let id = j.get("id").unwrap().as_i64().unwrap() as usize;
        let generated: Vec<i32> = j
            .get("generated")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_i64().unwrap() as i32)
            .collect();
        assert!(j.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("e2e_ms").unwrap().as_f64().unwrap() >= 0.0);
        seen.insert(id, (generated, j.get("stop").unwrap().as_str().unwrap().to_string()));
    }
    srv.join().unwrap();
    assert_eq!(seen.len(), n_requests, "client ids restored uniquely");
    for (i, p) in prompts.iter().enumerate() {
        let (generated, stop) = seen.get(&(100 + i)).expect("client id");
        let (want, want_stop) = SimEngine::expected_generation(&cfg, p, 10);
        assert_eq!(generated, &want, "request {i}");
        assert_eq!(stop, want_stop.as_str());
    }
}

#[test]
fn malformed_request_line_gets_error_reply() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group = sim_group(1);
    let cfg = ServeConfig { limit: Some(1), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, "{{\"id\": 1}}").unwrap(); // no prompt -> parse error
    // Over-long prompt (SimConfig max_seq = 512): must be rejected at
    // the server edge, not panic a shard.
    let long: Vec<String> = (0..600).map(|t| (t % 90).to_string()).collect();
    writeln!(conn, "{{\"id\": 9, \"prompt\": [{}]}}", long.join(", ")).unwrap();
    writeln!(conn, "{{\"id\": 2, \"prompt\": [1, 2, 3], \"max_new\": 6}}").unwrap();
    conn.flush().unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    // Error replies must be *valid* JSON even when the error message
    // itself contains quotes (e.g. `missing key "prompt"`).
    let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad reply {line:?}"));
    assert!(j.get("error").is_ok(), "got {line:?}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad reply {line:?}"));
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 9);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("too long"));
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 2);
    srv.join().unwrap();
}

// ---------------------------------------------------------------------
// New failure surfaces: idle/slow-loris eviction, connection cap, and
// admission overload — in-flight work must complete throughout.
// ---------------------------------------------------------------------

#[test]
fn slow_loris_is_evicted_while_inflight_request_completes() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // eos_every = 0 disables EOS: the busy request decodes exactly
    // max_new tokens -> ~100 steps x 2ms, far beyond the idle window.
    let sim_cfg = SimConfig { batch: 2, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, move |_| Ok(SimEngine::new(sim_cfg))).unwrap();
    let cfg = ServeConfig {
        max_conns: 8,
        idle_timeout: Duration::from_millis(150),
        limit: Some(1),
        ..Default::default()
    };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // The slow-loris: a partial request line, never finished.
    let mut loris = TcpStream::connect(addr).unwrap();
    loris.write_all(b"{\"id\": 5, \"prompt\": [1, ").unwrap();
    loris.flush().unwrap();

    // The busy client: one long-decoding request.
    let prompt = vec![2, 7, 18, 28];
    let mut busy = TcpStream::connect(addr).unwrap();
    writeln!(busy, "{}", request_line(3, &prompt, 100)).unwrap();
    busy.flush().unwrap();

    // The loris gets a structured goodbye, then EOF — while the busy
    // request is still decoding.
    let mut loris_reader = BufReader::new(loris);
    let mut line = String::new();
    loris_reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad goodbye {line:?}"));
    assert!(j.get("error").unwrap().as_str().unwrap().contains("idle timeout"),
            "got {line:?}");
    line.clear();
    assert_eq!(loris_reader.read_line(&mut line).unwrap(), 0,
               "loris must see EOF after the goodbye");

    // The in-flight request still completes, output exact.
    let mut busy_reader = BufReader::new(busy);
    line.clear();
    busy_reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad reply {line:?}"));
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 3);
    let generated: Vec<i32> = j
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_i64().unwrap() as i32)
        .collect();
    let (want, _) = SimEngine::expected_generation(&sim_cfg, &prompt, 100);
    assert_eq!(generated, want, "eviction must not disturb in-flight decode");
    srv.join().unwrap();
}

#[test]
fn connection_cap_rejects_excess_clients_while_decode_continues() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sim_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, move |_| Ok(SimEngine::new(sim_cfg))).unwrap();
    let cfg = ServeConfig {
        max_conns: 1,
        idle_timeout: Duration::from_secs(10),
        limit: Some(1),
        ..Default::default()
    };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // First client occupies the single slot with a long-running request.
    let prompt = vec![9, 4, 31];
    let mut first = TcpStream::connect(addr).unwrap();
    writeln!(first, "{}", request_line(1, &prompt, 60)).unwrap();
    first.flush().unwrap();
    // Give the reactor time to accept the first connection before the
    // second arrives (acceptance order = arrival order on one thread,
    // but the connect itself races the accept loop).
    std::thread::sleep(Duration::from_millis(50));

    // Second client: over the cap -> structured rejection + close.
    let second = TcpStream::connect(addr).unwrap();
    let mut second_reader = BufReader::new(second);
    let mut line = String::new();
    second_reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad reject {line:?}"));
    assert!(j.get("error").unwrap().as_str().unwrap()
             .contains("connection capacity"),
            "got {line:?}");
    line.clear();
    assert_eq!(second_reader.read_line(&mut line).unwrap(), 0,
               "rejected client must see EOF");

    // The first client's decode was never disturbed.
    let mut first_reader = BufReader::new(first);
    line.clear();
    first_reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad reply {line:?}"));
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 1);
    let generated: Vec<i32> = j
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_i64().unwrap() as i32)
        .collect();
    let (want, _) = SimEngine::expected_generation(&sim_cfg, &prompt, 60);
    assert_eq!(generated, want);
    srv.join().unwrap();
}

#[test]
fn burst_beyond_queue_depth_gets_structured_overloaded_replies() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Capacity = batch(1) + queue_depth(1) = 2 in-flight requests; the
    // slow engine guarantees neither completes while the burst lands.
    let sim_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let gcfg = GroupConfig { shards: 1, affinity_slack: 1, queue_depth: 1,
                             ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |_| Ok(SimEngine::new(sim_cfg)))
            .unwrap();
    let cfg = ServeConfig {
        max_conns: 8,
        idle_timeout: Duration::from_secs(10),
        limit: Some(2),
        ..Default::default()
    };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let n_burst = 8usize;
    let mut conn = TcpStream::connect(addr).unwrap();
    for i in 0..n_burst {
        writeln!(conn, "{}", request_line(i, &[5, 6, 7 + i as i32], 40)).unwrap();
    }
    conn.flush().unwrap();

    let mut reader = BufReader::new(conn);
    let mut served: BTreeMap<usize, Vec<i32>> = BTreeMap::new();
    let mut overloaded: Vec<usize> = Vec::new();
    for _ in 0..n_burst {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap_or_else(|_| panic!("bad reply {line:?}"));
        let id = j.get("id").unwrap().as_i64().unwrap() as usize;
        if let Ok(err) = j.get("error") {
            let msg = err.as_str().unwrap();
            assert!(msg.contains("overloaded"), "got {line:?}");
            assert!(msg.contains("queue-depth 1"), "got {line:?}");
            overloaded.push(id);
        } else {
            let generated: Vec<i32> = j
                .get("generated")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_i64().unwrap() as i32)
                .collect();
            served.insert(id, generated);
        }
    }
    let mut line = String::new();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "server must close");
    srv.join().unwrap();

    // Exactly the fleet capacity was admitted; the rest were refused
    // with structured errors, in burst order.
    assert_eq!(served.len(), 2, "capacity 2 must admit exactly 2: {served:?}");
    assert_eq!(overloaded.len(), n_burst - 2);
    assert_eq!(served.keys().copied().collect::<Vec<_>>(), vec![0, 1],
               "admission is FIFO over the burst");
    for (id, generated) in &served {
        let (want, _) = SimEngine::expected_generation(
            &sim_cfg, &[5, 6, 7 + *id as i32], 40);
        assert_eq!(generated, &want, "request {id}");
    }
}

// ---------------------------------------------------------------------
// Streaming lifecycle: delta parity, cancel-on-disconnect KV release,
// and per-request deadlines (ISSUE 4).
// ---------------------------------------------------------------------

/// Split `line` into `chunk`-byte writes with small pauses — adversarial
/// segmentation: the reactor must reassemble the frame from arbitrary
/// fragments.
fn write_segmented(conn: &mut TcpStream, line: &str, chunk: usize) {
    let bytes = line.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let end = (i + chunk).min(bytes.len());
        conn.write_all(&bytes[i..end]).unwrap();
        conn.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
        i = end;
    }
    conn.write_all(b"\n").unwrap();
    conn.flush().unwrap();
}

#[test]
fn streaming_deltas_concatenate_to_the_nonstreaming_reply() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group = sim_group(2);
    let cfg = ServeConfig { limit: Some(2), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let prompt = vec![6, 28, 496, 3];
    // Non-streaming baseline request on its own connection.
    let mut plain = TcpStream::connect(addr).unwrap();
    writeln!(plain, "{}", request_line(10, &prompt, 24)).unwrap();
    plain.flush().unwrap();

    // Streaming request, same prompt, written in 3-byte fragments.
    let mut stream = TcpStream::connect(addr).unwrap();
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let line = format!(
        "{{\"id\": 11, \"prompt\": [{}], \"max_new\": 24, \"stream\": true}}",
        toks.join(", "));
    write_segmented(&mut stream, &line, 3);

    // Drain the streaming connection: delta frames, then the terminal
    // reply (the only line carrying "stop").
    let mut deltas: Vec<i32> = Vec::new();
    let mut reader = BufReader::new(stream);
    let terminal = loop {
        let mut l = String::new();
        assert!(reader.read_line(&mut l).unwrap() > 0,
                "EOF before terminal reply");
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
        assert!(j.get("error").is_err(), "unexpected error {l:?}");
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 11,
                   "client id restored on every frame");
        if j.opt("stop").is_some() {
            break j;
        }
        assert_eq!(j.get("index").unwrap().as_i64().unwrap() as usize,
                   deltas.len(), "delta frames arrive in order");
        for t in j.get("delta").unwrap().as_arr().unwrap() {
            deltas.push(t.as_i64().unwrap() as i32);
        }
    };
    assert!(!deltas.is_empty(), "at least one delta before Finished");

    let mut plain_reader = BufReader::new(plain);
    let mut l = String::new();
    plain_reader.read_line(&mut l).unwrap();
    let j = Json::parse(&l).unwrap();
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 10);
    let plain_gen: Vec<i32> = j
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    srv.join().unwrap();

    let stream_gen: Vec<i32> = terminal
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    assert_eq!(deltas, stream_gen,
               "concatenated deltas != streaming terminal reply");
    assert_eq!(stream_gen, plain_gen,
               "streaming and non-streaming replies diverged");
    assert_eq!(terminal.get("stop").unwrap().as_str().unwrap(),
               j.get("stop").unwrap().as_str().unwrap());
    let (want, _) =
        SimEngine::expected_generation(&SimConfig::default(), &prompt, 24);
    assert_eq!(plain_gen, want, "both must equal the sim reference");
}

#[test]
fn disconnect_mid_decode_cancels_and_releases_kv_pages() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Slow single-slot engine: the request decodes for ~1s unless
    // cancelled. The shared gauge watches its simulated KV pool from
    // outside the shard thread.
    let sim_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let capacity = sim_cfg.batch * sim_cfg.pages_per_slot;
    let gauge = Arc::new(AtomicUsize::new(0));
    let factory_gauge = gauge.clone();
    let group: EngineGroup<SimEngine> = EngineGroup::new(1, move |_| {
        Ok(SimEngine::with_pool_gauge(sim_cfg, factory_gauge.clone()))
    })
    .unwrap();
    // limit 1: the cancelled completion is the only one the server needs
    // to collect before draining and shutting down.
    let cfg = ServeConfig {
        max_conns: 4,
        idle_timeout: Duration::from_secs(10),
        limit: Some(1),
        ..Default::default()
    };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // Streaming request so the client *knows* decode is in progress
    // before disconnecting.
    let conn = TcpStream::connect(addr).unwrap();
    {
        let mut w = conn.try_clone().unwrap();
        writeln!(w, "{{\"id\": 1, \"prompt\": [3, 7, 9], \"max_new\": 500, \
                     \"stream\": true}}")
            .unwrap();
        w.flush().unwrap();
    }
    let mut reader = BufReader::new(conn);
    let mut l = String::new();
    reader.read_line(&mut l).unwrap();
    let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
    assert!(j.get("delta").is_ok(), "expected a delta frame, got {l:?}");
    assert_eq!(gauge.load(Ordering::SeqCst), capacity - sim_cfg.pages_per_slot,
               "mid-decode the slot must hold its pages");

    // Disconnect mid-generation: both socket halves close; the server
    // reads EOF and must propagate a cancel instead of orphaning the
    // ~1s decode (limit=1 means the server only exits if the cancel
    // produces the completion).
    drop(reader);
    srv.join().unwrap();
    assert_eq!(gauge.load(Ordering::SeqCst), capacity,
               "cancelled request must release its KV pages");
}

#[test]
fn per_request_deadline_returns_partial_generation_over_socket() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sim_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, move |_| Ok(SimEngine::new(sim_cfg))).unwrap();
    let cfg = ServeConfig { limit: Some(1), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    // Without the deadline this would decode for ~100000 steps; the
    // 40ms deadline must cut it short with a partial reply.
    writeln!(conn, "{{\"id\": 4, \"prompt\": [2, 4, 8], \"max_new\": 100000, \
                   \"deadline_ms\": 40}}")
        .unwrap();
    conn.flush().unwrap();
    let mut reader = BufReader::new(conn);
    let mut l = String::new();
    reader.read_line(&mut l).unwrap();
    let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad reply {l:?}"));
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 4);
    assert_eq!(j.get("stop").unwrap().as_str().unwrap(), "deadline");
    let n = j.get("generated").unwrap().as_arr().unwrap().len();
    assert!(n < 100_000, "deadline must stop the decode early (got {n})");
    srv.join().unwrap();
}

// ---------------------------------------------------------------------
// Memory-planned admission, priority preemption, and deterministic
// fault injection (ISSUE 6).
// ---------------------------------------------------------------------

/// Seeds for the chaos sweep: `SEERATTN_CHAOS_SEEDS` (comma-separated)
/// lets CI pin its matrix; the fallback keeps local runs fast and fixed.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("SEERATTN_CHAOS_SEEDS") {
        Ok(s) => {
            let seeds: Vec<u64> =
                s.split(',').filter_map(|t| t.trim().parse().ok()).collect();
            assert!(!seeds.is_empty(), "SEERATTN_CHAOS_SEEDS set but unusable");
            seeds
        }
        Err(_) => vec![3, 17, 1999],
    }
}

/// Lane count for the chaos sweep: `SEERATTN_REACTORS` lets CI run the
/// same fault schedule through the multi-lane client partitioning the
/// multi-reactor front end uses (`run_lanes`); the default of 1
/// preserves the single-lane `run_group` path exactly.
fn chaos_reactors() -> usize {
    std::env::var("SEERATTN_REACTORS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// A trace whose every request is individually servable (projected peak
/// of 3-4 pages, at most half the 8-page per-shard pool, so it survives
/// the worst seeded `ShrinkPool`) while the aggregate in-flight demand
/// oversubscribes the fleet's page pools ~2x. Every 5th entry is a
/// long-prompt / short-decode request (17-24 prompt tokens over the
/// chaos configs' 8-token prefill chunk, still a 4-page projection), so
/// the fault matrix lands preemptions and cancellations on half-prefilled
/// slots, not just mid-decode ones.
fn chaos_trace(n: usize, seed: u64) -> Vec<TracedRequest> {
    let mut rng = Rng::new(seed ^ 0x00C0_FFEE);
    (0..n)
        .map(|i| {
            let (plen, max_new) = if i % 5 == 4 {
                (rng.range(17, 25), 7) // ceil((24 + 7 + 1) / 8) = 4 pages
            } else {
                (rng.range(4, 15), 16)
            };
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.range(4, 90) as i32).collect();
            TracedRequest {
                arrival_s: 0.0,
                episode: Episode { prompt, target: Vec::new(), answer: 0,
                                   cfg: TaskConfig::easy() },
                max_new,
            }
        })
        .collect()
}

#[test]
fn chaos_oversubscribed_group_never_loses_a_request() {
    for seed in chaos_seeds() {
        let n = 24usize;
        let trace = chaos_trace(n, seed);
        let sim_cfg = SimConfig {
            batch: 2,
            pages_per_slot: 4, // pool = 8 pages per shard
            page_tokens: 8,
            eos_every: 0,
            step_delay_ms: 1,
            preempt_retries: 2,
            faults: FaultSchedule::seeded(seed, 8),
            // Long chaos_trace prompts span 3 chunks, so the seeded
            // faults hit slots in every prefill phase.
            prefill_chunk: 8,
            ..Default::default()
        };
        let lanes = chaos_reactors();
        let gcfg = GroupConfig { shards: 4, queue_depth: 2, lanes,
                                 ..Default::default() };
        // Run under a watchdog: the property under test is liveness, so
        // a regression would hang the suite instead of failing it.
        let expect = trace.clone();
        let worker = std::thread::spawn(move || {
            let group: EngineGroup<SimEngine> =
                EngineGroup::with_config(gcfg,
                                         move |_| Ok(SimEngine::new(sim_cfg)))
                    .unwrap();
            let runner =
                TraceRunner { replay: Replay::Virtual, ..Default::default() };
            if lanes == 1 {
                let mut group = group;
                let comps = runner.run_group(&mut group, &trace).unwrap();
                let gm = group.shutdown().unwrap();
                (comps, gm)
            } else {
                let mut views = group.into_lanes();
                let comps = runner.run_lanes(&mut views, &trace).unwrap();
                let primary = views.remove(0);
                drop(views);
                let gm = primary.shutdown().unwrap();
                (comps, gm)
            }
        });
        let deadline = Instant::now() + Duration::from_secs(60);
        while !worker.is_finished() {
            assert!(Instant::now() < deadline,
                    "seed {seed}: chaos replay deadlocked");
            std::thread::sleep(Duration::from_millis(10));
        }
        let (comps, _gm) = worker.join().unwrap();
        let comps = by_id(comps); // also asserts no duplicated ids
        assert_eq!(comps.len(), n, "seed {seed}: a request was lost");
        for (id, (plen, generated, stop)) in &comps {
            let t = &expect[*id as usize];
            assert_eq!(*plen, t.episode.prompt.len(), "seed {seed} id {id}");
            let (want, want_stop) = SimEngine::expected_generation(
                &sim_cfg, &t.episode.prompt, t.max_new);
            match stop {
                StopReason::Eos | StopReason::MaxNewTokens
                | StopReason::ContextFull => {
                    assert_eq!(stop, &want_stop, "seed {seed} id {id}");
                    assert_eq!(generated, &want,
                               "seed {seed} id {id}: preempt/resume broke \
                                bit-identity");
                }
                // Retry budget spent under injected pressure: terminal,
                // partial, and still a prefix of the pure token function.
                StopReason::ResourceExhausted => {
                    assert!(want.starts_with(generated),
                            "seed {seed} id {id}: exhausted completion \
                             diverged from the token function");
                }
                StopReason::Cancelled | StopReason::DeadlineExceeded => {
                    panic!("seed {seed} id {id}: stop {stop:?} without a \
                            cancel or deadline")
                }
            }
        }
    }
}

#[test]
fn page_pressure_defers_then_serves_every_request() {
    // One shard, pool = 8 pages, 6-page requests: admission count
    // headroom (batch 2 + queue_depth 2 = 4) outlives the page budget
    // (pool 8 + 2 queue shares of 4 = 16 pages, so two 6-page
    // reservations fit and the third defers). The trace runner must
    // absorb `Deferred` via its backoff loop without losing an entry.
    let sim_cfg = SimConfig { batch: 2, pages_per_slot: 4, page_tokens: 8,
                              eos_every: 0, step_delay_ms: 1,
                              ..Default::default() };
    let gcfg = GroupConfig { shards: 1, queue_depth: 2, ..Default::default() };
    let trace: Vec<TracedRequest> = (0..10)
        .map(|i| TracedRequest {
            arrival_s: 0.0,
            episode: Episode { prompt: vec![2, 5 + i as i32, 9],
                               target: Vec::new(), answer: 0,
                               cfg: TaskConfig::easy() },
            max_new: 44, // ceil((3 + 44 + 1) / 8) = 6 pages
        })
        .collect();
    let mut group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |_| Ok(SimEngine::new(sim_cfg)))
            .unwrap();
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let comps = by_id(runner.run_group(&mut group, &trace).unwrap());
    let deferred = group.deferred();
    let gm = group.shutdown().unwrap();
    assert_eq!(comps.len(), trace.len());
    for (id, (_plen, generated, stop)) in &comps {
        let t = &trace[*id as usize];
        let (want, want_stop) = SimEngine::expected_generation(
            &sim_cfg, &t.episode.prompt, t.max_new);
        assert_eq!(generated, &want, "id {id}");
        assert_eq!(stop, &want_stop, "id {id}");
    }
    assert!(deferred >= 1,
            "the 16-page budget must defer a third 6-page reservation");
    assert_eq!(gm.deferred, deferred, "deferral count must reach the report");
}

#[test]
fn cancel_storm_on_oversubscribed_group_leaks_no_pages() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let shards = 4usize;
    let sim_cfg = SimConfig { batch: 2, pages_per_slot: 4, page_tokens: 8,
                              eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let capacity = sim_cfg.batch * sim_cfg.pages_per_slot;
    let gauges: Vec<Arc<AtomicUsize>> =
        (0..shards).map(|_| Arc::new(AtomicUsize::new(0))).collect();
    let factory_gauges = gauges.clone();
    let gcfg = GroupConfig { shards, queue_depth: 2, ..Default::default() };
    let mut group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |shard| {
            Ok(SimEngine::with_pool_gauge(sim_cfg,
                                          factory_gauges[shard].clone()))
        })
        .unwrap();

    // 16 six-page requests against 4 pools of 8 pages: submission has to
    // ride the deferral/backpressure loop, and then every request —
    // active, queued at a shard, or queued in an engine — is cancelled.
    let mut settled = Vec::new();
    let n = 16u64;
    for i in 0..n {
        let prompt = vec![3, 1 + i as i32, 7];
        loop {
            match group.submit(Request::new(i, prompt.clone(), 44)).unwrap() {
                SubmitOutcome::Routed(_) => break,
                SubmitOutcome::Deferred { .. } | SubmitOutcome::Rejected => {
                    // Saturated: let decode free budget, keep the
                    // completion channel drained.
                    if let Some(c) =
                        group.poll(Duration::from_millis(1)).unwrap()
                    {
                        settled.push(c);
                    }
                }
            }
        }
    }
    for id in 0..n {
        group.cancel(id);
    }
    settled.extend(group.drain().unwrap());
    let comps = by_id(settled); // also asserts no duplicated ids
    assert_eq!(comps.len(), n as usize, "a cancelled request went missing");
    for (id, (_plen, _generated, stop)) in &comps {
        assert!(matches!(stop, StopReason::Cancelled | StopReason::Eos
                               | StopReason::MaxNewTokens),
                "request {id}: unexpected stop {stop:?}");
    }
    group.shutdown().unwrap();
    for (i, g) in gauges.iter().enumerate() {
        assert_eq!(g.load(Ordering::SeqCst), capacity,
                   "shard {i} leaked simulated KV pages");
    }
}

#[test]
fn disconnect_storm_releases_pages_on_every_shard() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let shards = 2usize;
    let sim_cfg = SimConfig { batch: 1, pages_per_slot: 8, page_tokens: 16,
                              eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let capacity = sim_cfg.batch * sim_cfg.pages_per_slot;
    let gauges: Vec<Arc<AtomicUsize>> =
        (0..shards).map(|_| Arc::new(AtomicUsize::new(0))).collect();
    let factory_gauges = gauges.clone();
    let gcfg = GroupConfig { shards, ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |shard| {
            Ok(SimEngine::with_pool_gauge(sim_cfg,
                                          factory_gauges[shard].clone()))
        })
        .unwrap();
    let n_clients = 6usize;
    let cfg = ServeConfig {
        max_conns: 16,
        idle_timeout: Duration::from_secs(10),
        limit: Some(n_clients),
        ..Default::default()
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // Six streaming clients, each wanting ceil((3 + 60 + 1) / 16) = 4
    // pages for a ~120ms decode; one decodes per shard, the rest queue.
    // Read one delta from the first client so decode is provably in
    // progress, then slam every connection shut at once.
    let mut conns: Vec<TcpStream> = Vec::new();
    for i in 0..n_clients {
        let mut c = TcpStream::connect(addr).unwrap();
        writeln!(c,
                 "{{\"id\": {}, \"prompt\": [2, {}, 5], \"max_new\": 60, \
                  \"stream\": true}}",
                 30 + i, 10 + i)
            .unwrap();
        c.flush().unwrap();
        conns.push(c);
    }
    {
        let mut reader = BufReader::new(conns[0].try_clone().unwrap());
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
        assert!(j.get("delta").is_ok(), "expected a delta frame, got {l:?}");
    }
    drop(conns); // the storm: every client vanishes at once

    // limit = n_clients: the server can only exit if every request —
    // decoding or still queued — resolves to a completion.
    srv.join().unwrap();
    for (i, g) in gauges.iter().enumerate() {
        assert_eq!(g.load(Ordering::SeqCst), capacity,
                   "shard {i} leaked simulated KV pages");
    }
}

#[test]
fn page_deferral_and_priority_errors_are_structured_over_sockets() {
    let sim_cfg = SimConfig { batch: 2, pages_per_slot: 4, page_tokens: 8,
                              eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let gcfg = GroupConfig { shards: 1, queue_depth: 2, ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |_| Ok(SimEngine::new(sim_cfg)))
            .unwrap();
    let cfg = ServeConfig { limit: Some(2), ..Default::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    // An unknown priority class is a parse error, not a shard panic.
    writeln!(conn, "{{\"id\": 90, \"prompt\": [1, 2], \"max_new\": 4, \
                   \"priority\": \"urgent\"}}")
        .unwrap();
    // Two 6-page requests fit the 16-page budget; the third must come
    // back `deferred`, carrying the router's retry hint.
    for id in [91, 92, 93] {
        writeln!(conn, "{{\"id\": {id}, \"prompt\": [3, {id}, 8], \
                       \"max_new\": 44, \"priority\": \"batch\"}}")
            .unwrap();
    }
    conn.flush().unwrap();

    let mut replies: BTreeMap<i64, Json> = BTreeMap::new();
    let mut reader = BufReader::new(conn);
    for _ in 0..4 {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad reply {l:?}"));
        replies.insert(j.get("id").unwrap().as_i64().unwrap(), j);
    }
    srv.join().unwrap();

    let bad = &replies[&90];
    assert!(bad.get("error").unwrap().as_str().unwrap().contains("priority"),
            "unknown priority class must fail at parse");
    let deferred = &replies[&93];
    let msg = deferred.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains("deferred"), "got {msg:?}");
    assert_eq!(deferred.get("retry_after_ms").unwrap().as_i64().unwrap(), 25,
               "deferred replies must carry the router's retry hint");
    for id in [91i64, 92] {
        let j = &replies[&id];
        let generated: Vec<i32> = j
            .get("generated").unwrap().as_arr().unwrap()
            .iter().map(|t| t.as_i64().unwrap() as i32).collect();
        let (want, _) = SimEngine::expected_generation(
            &sim_cfg, &[3, id as i32, 8], 44);
        assert_eq!(generated, &want, "request {id}");
    }
}

// ---------------------------------------------------------------------
// Chunked prefill: interleaved admission must change nothing a client
// can observe.
// ---------------------------------------------------------------------

/// Long-prompt + short-decode entries interleaved with short-prompt +
/// long-decode ones — the mix where monolithic prefill stalls every
/// in-flight decode behind one big admission.
fn long_short_trace(n: usize, seed: u64) -> Vec<TracedRequest> {
    let mut rng = Rng::new(seed ^ 0x0C0D_ED0C);
    (0..n)
        .map(|i| {
            let (plen, max_new) = if i % 3 == 0 {
                (rng.range(40, 81), 4)
            } else {
                (rng.range(4, 10), 24)
            };
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.range(4, 90) as i32).collect();
            TracedRequest {
                arrival_s: 0.0,
                episode: Episode { prompt, target: Vec::new(), answer: 0,
                                   cfg: TaskConfig::easy() },
                max_new,
            }
        })
        .collect()
}

#[test]
fn chunked_prefill_matches_monolithic_across_a_sharded_group() {
    let n = 24usize;
    let trace = long_short_trace(n, 11);
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let run = |chunk: usize| {
        let sim_cfg = SimConfig { batch: 2, eos_every: 0,
                                  prefill_chunk: chunk,
                                  ..Default::default() };
        let gcfg = GroupConfig { shards: 4, queue_depth: 2,
                                 ..Default::default() };
        let mut group: EngineGroup<SimEngine> =
            EngineGroup::with_config(gcfg,
                                     move |_| Ok(SimEngine::new(sim_cfg)))
                .unwrap();
        let comps = by_id(runner.run_group(&mut group, &trace).unwrap());
        let gm = group.shutdown().unwrap();
        (comps, gm.fleet().prefill_chunks, gm.fleet().prefill_tokens)
    };
    let (chunked, chunks_c, toks_c) = run(8);
    let (mono, chunks_m, toks_m) = run(0);
    assert_eq!(chunked.len(), n);
    assert_eq!(mono.len(), n);
    for (id, want) in &mono {
        assert_eq!(chunked.get(id).expect("missing id"), want,
                   "id {id}: chunked prefill changed the stream");
    }
    // No preemption in this mix, so both modes prefill the same tokens;
    // the chunked run just spreads them over more steps.
    assert_eq!(toks_c, toks_m, "same tokens prefilled either way");
    assert!(chunks_c > chunks_m,
            "40-80-token prompts over an 8-token chunk must take more \
             chunk steps ({chunks_c} vs {chunks_m})");
}

#[test]
fn chunked_prefill_preserves_finish_order_and_streams_on_one_engine() {
    // Four concurrent slots with widely separated decode lengths: the
    // chunk phase shifts first tokens by at most ceil(80/8) = 10 steps,
    // far less than the 40-step finish spacing, so completion order is
    // a stable property of this trace — and must survive chunking. The
    // single-engine runner steps deterministically (no shard threads),
    // making the order assertion exact.
    let mk = |plen: usize, max_new: usize| TracedRequest {
        arrival_s: 0.0,
        episode: Episode {
            prompt: (0..plen as i32).map(|t| 3 + t).collect(),
            target: Vec::new(),
            answer: 0,
            cfg: TaskConfig::easy(),
        },
        max_new,
    };
    let trace = vec![mk(8, 5), mk(16, 45), mk(24, 85), mk(32, 125)];
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let run = |chunk: usize| {
        let mut eng = SimEngine::new(SimConfig { batch: 4, eos_every: 0,
                                                 prefill_chunk: chunk,
                                                 ..Default::default() });
        let comps = runner.run(&mut eng, &trace).unwrap();
        (comps, eng.metrics.prefill_chunks)
    };
    let (chunked, chunks_c) = run(8);
    let (mono, chunks_m) = run(0);
    let ids = |comps: &[Completion]| -> Vec<u64> {
        comps.iter().map(|c| c.id).collect()
    };
    assert_eq!(ids(&chunked), ids(&mono),
               "chunked prefill must not reorder completions");
    for (a, b) in chunked.iter().zip(&mono) {
        assert_eq!(a.generated, b.generated, "id {}: stream diverged", a.id);
        assert_eq!(a.stop, b.stop, "id {}", a.id);
    }
    assert!(chunks_c > chunks_m,
            "80 effective prefill tokens over 8-token chunks must take \
             more chunk steps ({chunks_c} vs {chunks_m})");
}

#[test]
fn batch_stream_is_preempted_resumed_and_bit_identical_over_sockets() {
    let sim_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, move |_| Ok(SimEngine::new(sim_cfg))).unwrap();
    let cfg = ServeConfig { limit: Some(2), ..Default::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // A batch-class streaming request occupies the single slot...
    let prompt = vec![4, 9, 2];
    let batch_conn = TcpStream::connect(addr).unwrap();
    {
        let mut w = batch_conn.try_clone().unwrap();
        writeln!(w, "{{\"id\": 70, \"prompt\": [4, 9, 2], \"max_new\": 120, \
                     \"stream\": true, \"priority\": \"batch\"}}")
            .unwrap();
        w.flush().unwrap();
    }
    let mut reader = BufReader::new(batch_conn);
    let mut l = String::new();
    reader.read_line(&mut l).unwrap();
    let first = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
    assert!(first.get("delta").is_ok(), "expected a delta, got {l:?}");
    let mut deltas: Vec<i32> = Vec::new();
    for t in first.get("delta").unwrap().as_arr().unwrap() {
        deltas.push(t.as_i64().unwrap() as i32);
    }

    // ...then an interactive request arrives: the engine must evict the
    // batch slot for it at a step boundary, announce the preemption on
    // the stream, and resume the stream with no gap and no repeat.
    let other = vec![8, 1, 5];
    let mut inter = TcpStream::connect(addr).unwrap();
    writeln!(inter, "{}", request_line(71, &other, 8)).unwrap();
    inter.flush().unwrap();

    let mut preemptions = 0usize;
    let terminal = loop {
        l.clear();
        assert!(reader.read_line(&mut l).unwrap() > 0,
                "EOF before the batch request's terminal reply");
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 70);
        if j.opt("stop").is_some() {
            break j;
        }
        if let Some(ev) = j.opt("event") {
            assert_eq!(ev.as_str().unwrap(), "preempted");
            preemptions += 1;
            continue;
        }
        assert_eq!(j.get("index").unwrap().as_i64().unwrap() as usize,
                   deltas.len(),
                   "token indices must stay contiguous across preemption");
        for t in j.get("delta").unwrap().as_arr().unwrap() {
            deltas.push(t.as_i64().unwrap() as i32);
        }
    };

    // The interactive request was served from under the batch stream.
    let mut inter_reader = BufReader::new(inter);
    l.clear();
    inter_reader.read_line(&mut l).unwrap();
    let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad reply {l:?}"));
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 71);
    let inter_gen: Vec<i32> = j
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    srv.join().unwrap();

    assert!(preemptions >= 1,
            "the interactive arrival must preempt the batch stream");
    let (want, want_stop) =
        SimEngine::expected_generation(&sim_cfg, &prompt, 120);
    let term_gen: Vec<i32> = terminal
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    assert_eq!(deltas, term_gen,
               "concatenated deltas != terminal generation");
    assert_eq!(term_gen, want,
               "preempt/resume must keep the stream bit-identical");
    assert_eq!(terminal.get("stop").unwrap().as_str().unwrap(),
               want_stop.as_str());
    let (want_inter, _) = SimEngine::expected_generation(&sim_cfg, &other, 8);
    assert_eq!(inter_gen, want_inter);
}

// ---------------------------------------------------------------------
// Content-addressed prefix cache (ISSUE 8): shared block-aligned prompt
// heads are prefilled once and spliced into every later admission —
// quiet case, chaos fault matrix, and cancel storms that must leak
// neither pages nor pins.
// ---------------------------------------------------------------------

/// `n` requests sharing a 4-block (32-token) head with distinct 3-token
/// tails; block size (`page_tokens`) is 8 in the prefix tests.
fn shared_head_trace(n: usize) -> Vec<TracedRequest> {
    let head: Vec<i32> = (0..32).map(|t| 10 + t).collect();
    (0..n)
        .map(|i| {
            let mut prompt = head.clone();
            prompt.extend([100 + i as i32, 55, 60 + i as i32]);
            TracedRequest {
                arrival_s: 0.0,
                episode: Episode { prompt, target: Vec::new(), answer: 0,
                                   cfg: TaskConfig::easy() },
                max_new: 10,
            }
        })
        .collect()
}

#[test]
fn prefix_cache_prefills_shared_head_once_and_streams_bit_identical() {
    // Six 35-token requests sharing a 4-block head on a 4-shard group.
    // Prefix-affinity routing sends all of them to one shard (warm
    // blocks widen the affinity window, sticky placement keeps thieves
    // off), batch 1 serialises admission there, so the first request
    // publishes the head and the other five splice it: total prefill
    // work is one full prompt plus five 3-token tails — with output
    // bit-identical to the cold-cache run.
    let n = 6usize;
    let trace = shared_head_trace(n);
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let run = |cache: bool| {
        let sim_cfg = SimConfig {
            batch: 1,
            pages_per_slot: 12, // 6 active + 4 cached pages fit: no eviction
            page_tokens: 8,
            eos_every: 0,
            prefill_chunk: 8,
            prefix_cache: cache,
            ..Default::default()
        };
        let gcfg = GroupConfig { shards: 4, queue_depth: 8,
                                 prefix_routing: cache,
                                 ..Default::default() };
        let mut group: EngineGroup<SimEngine> =
            EngineGroup::with_config(gcfg,
                                     move |_| Ok(SimEngine::new(sim_cfg)))
                .unwrap();
        let comps = by_id(runner.run_group(&mut group, &trace).unwrap());
        (comps, group.shutdown().unwrap())
    };
    let (cold, gm_cold) = run(false);
    let (warm, gm_warm) = run(true);
    assert_eq!(cold.len(), n);
    for (id, want) in &cold {
        assert_eq!(warm.get(id).expect("missing id"), want,
                   "id {id}: prefix reuse changed the stream");
    }
    let (fc, fw) = (gm_cold.fleet(), gm_warm.fleet());
    assert_eq!(fc.prefix_hits, 0, "cold run must not touch the cache");
    assert_eq!(fw.prefix_hits, (n - 1) as u64, "every repeat hits");
    assert_eq!(fw.prefix_blocks_reused, 4 * (n - 1) as u64);
    assert_eq!(fw.prefix_evictions, 0, "a 12-page pool never pressures \
                                        a 4-block cache");
    assert_eq!(fc.prefill_tokens, (n * 35) as u64);
    assert_eq!(fw.prefill_tokens, (35 + (n - 1) * 3) as u64,
               "one full prefill + n-1 small tails");
    assert_eq!(fc.prefill_tokens - fw.prefill_tokens,
               8 * fw.prefix_blocks_reused,
               "every reused block saves exactly one block of prefill");
}

/// The chaos mix with a shared 2-block (16-token) head: four of every
/// five requests extend the head with a random 1-7 token tail, the
/// fifth is a random long prompt — all projecting at most 4 pages, so
/// each survives the worst seeded `ShrinkPool` alone while the fault
/// matrix lands preemptions and cancellations on half-prefilled
/// shared-prefix slots.
fn prefix_chaos_trace(n: usize, seed: u64) -> Vec<TracedRequest> {
    let mut rng = Rng::new(seed ^ 0x00C0_FFEE);
    let head: Vec<i32> = (0..16).map(|t| 30 + t).collect();
    (0..n)
        .map(|i| {
            let prompt: Vec<i32> = if i % 5 == 4 {
                let plen = rng.range(17, 25);
                (0..plen).map(|_| rng.range(4, 90) as i32).collect()
            } else {
                let mut p = head.clone();
                let tail = rng.range(1, 8);
                p.extend((0..tail).map(|_| rng.range(4, 90) as i32));
                p
            };
            TracedRequest {
                arrival_s: 0.0,
                episode: Episode { prompt, target: Vec::new(), answer: 0,
                                   cfg: TaskConfig::easy() },
                max_new: 7, // <= (24 + 7 + 1) / 8 = 4 pages either way
            }
        })
        .collect()
}

#[test]
fn prefix_cache_chaos_matrix_keeps_streams_bit_identical_to_cold() {
    // The ISSUE 6 chaos property with the prefix cache in the loop:
    // under 2x oversubscription and seeded stall/shrink/fail-admit
    // faults, warm-spliced, preempted, and resumed requests all stay
    // bit-identical to the pure token function — which IS the
    // cold-cache stream — and nothing is lost or duplicated.
    for seed in chaos_seeds() {
        let n = 24usize;
        let trace = prefix_chaos_trace(n, seed);
        let sim_cfg = SimConfig {
            batch: 2,
            pages_per_slot: 4, // pool = 8 pages per shard
            page_tokens: 8,
            eos_every: 0,
            step_delay_ms: 1,
            preempt_retries: 2,
            faults: FaultSchedule::seeded(seed, 8),
            prefill_chunk: 8,
            prefix_cache: true,
            ..Default::default()
        };
        let gcfg = GroupConfig { shards: 4, queue_depth: 2,
                                 prefix_routing: true,
                                 ..Default::default() };
        let expect = trace.clone();
        let worker = std::thread::spawn(move || {
            let mut group: EngineGroup<SimEngine> =
                EngineGroup::with_config(gcfg,
                                         move |_| Ok(SimEngine::new(sim_cfg)))
                    .unwrap();
            let runner =
                TraceRunner { replay: Replay::Virtual, ..Default::default() };
            let comps = runner.run_group(&mut group, &trace).unwrap();
            let gm = group.shutdown().unwrap();
            (comps, gm)
        });
        let deadline = Instant::now() + Duration::from_secs(60);
        while !worker.is_finished() {
            assert!(Instant::now() < deadline,
                    "seed {seed}: prefix chaos replay deadlocked");
            std::thread::sleep(Duration::from_millis(10));
        }
        let (comps, gm) = worker.join().unwrap();
        let comps = by_id(comps); // also asserts no duplicated ids
        assert_eq!(comps.len(), n, "seed {seed}: a request was lost");
        for (id, (plen, generated, stop)) in &comps {
            let t = &expect[*id as usize];
            assert_eq!(*plen, t.episode.prompt.len(), "seed {seed} id {id}");
            let (want, want_stop) = SimEngine::expected_generation(
                &sim_cfg, &t.episode.prompt, t.max_new);
            match stop {
                StopReason::Eos | StopReason::MaxNewTokens
                | StopReason::ContextFull => {
                    assert_eq!(stop, &want_stop, "seed {seed} id {id}");
                    assert_eq!(generated, &want,
                               "seed {seed} id {id}: prefix splice or \
                                preempt/resume broke bit-identity");
                }
                StopReason::ResourceExhausted => {
                    assert!(want.starts_with(generated),
                            "seed {seed} id {id}: exhausted completion \
                             diverged from the token function");
                }
                StopReason::Cancelled | StopReason::DeadlineExceeded => {
                    panic!("seed {seed} id {id}: stop {stop:?} without a \
                            cancel or deadline")
                }
            }
        }
        // 19 of 24 requests share the head: the cache must actually have
        // engaged under the fault matrix, not silently disabled itself.
        assert!(gm.fleet().prefix_hits >= 1,
                "seed {seed}: chaos run never reused the shared head");
    }
}

#[test]
fn prefix_cancel_storm_leaks_neither_pages_nor_pins() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    // A cancel storm on shared-prefix requests — many of them cancelled
    // half-prefilled, pinning cached head blocks — followed by a leak
    // probe: two fresh requests whose projections sum to the whole pool.
    // They can only be admitted if every storm slot released its pages
    // AND every cache pin was dropped (a leaked pin would make the
    // cached blocks unevictable and wedge admission: the watchdog turns
    // that into a failure). Their admission also forcibly evicts the
    // leftover cache, so afterwards the gauge must sit at full capacity.
    let sim_cfg = SimConfig {
        batch: 2,
        pages_per_slot: 8, // pool = 16 pages
        page_tokens: 8,
        eos_every: 0,
        step_delay_ms: 2,
        prefill_chunk: 8,
        prefix_cache: true,
        ..Default::default()
    };
    let capacity = sim_cfg.batch * sim_cfg.pages_per_slot;
    let gauge = Arc::new(AtomicUsize::new(0));
    let factory_gauge = gauge.clone();
    let gcfg = GroupConfig { shards: 1, queue_depth: 2,
                             prefix_routing: true, ..Default::default() };
    let worker = std::thread::spawn(move || {
        let mut group: EngineGroup<SimEngine> =
            EngineGroup::with_config(gcfg, move |_| {
                Ok(SimEngine::with_pool_gauge(sim_cfg, factory_gauge.clone()))
            })
            .unwrap();
        // Storm: sixteen 31-token requests sharing a 2-block head with
        // divergent 15-token tails (6-page projections against a
        // 32-page budget: submission rides the deferral loop; multi-
        // chunk tails keep slots half-prefilled long enough for cancels
        // to land on them), then cancel every one of them.
        let head: Vec<i32> = (0..16).map(|t| 50 + t).collect();
        let mut settled = Vec::new();
        let n = 16u64;
        for i in 0..n {
            let mut prompt = head.clone();
            prompt.push(200 + i as i32);
            prompt.extend((0..14).map(|t| 210 + ((i as i32 + t) % 40)));
            loop {
                match group.submit(Request::new(i, prompt.clone(), 12)).unwrap() {
                    SubmitOutcome::Routed(_) => break,
                    SubmitOutcome::Deferred { .. } | SubmitOutcome::Rejected => {
                        if let Some(c) =
                            group.poll(Duration::from_millis(1)).unwrap()
                        {
                            settled.push(c);
                        }
                    }
                }
            }
        }
        for id in 0..n {
            group.cancel(id);
        }
        settled.extend(group.drain().unwrap());
        // Leak probe: 2 x 8-page requests = the whole pool. The second
        // admission must evict whatever the storm left cached.
        for i in 0..2u64 {
            loop {
                match group
                    .submit(Request::new(100 + i, vec![3, 7 + i as i32, 11], 60))
                    .unwrap()
                {
                    SubmitOutcome::Routed(_) => break,
                    SubmitOutcome::Deferred { .. } | SubmitOutcome::Rejected => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
        }
        settled.extend(group.drain().unwrap());
        let gm = group.shutdown().unwrap();
        (settled, gm)
    });
    let deadline = Instant::now() + Duration::from_secs(60);
    while !worker.is_finished() {
        assert!(Instant::now() < deadline,
                "a leaked page or cache pin wedged admission");
        std::thread::sleep(Duration::from_millis(10));
    }
    let (settled, gm) = worker.join().unwrap();
    let comps = by_id(settled); // also asserts no duplicated ids
    assert_eq!(comps.len(), 18, "a request went missing in the storm");
    for i in 0..2u64 {
        let (_plen, generated, stop) = comps.get(&(100 + i)).unwrap();
        let (want, want_stop) = SimEngine::expected_generation(
            &sim_cfg, &[3, 7 + i as i32, 11], 60);
        assert_eq!(generated, &want, "probe {i}: stream diverged");
        assert_eq!(stop, &want_stop, "probe {i}");
    }
    assert!(gm.fleet().prefix_hits >= 1,
            "the storm must actually have exercised the cache");
    assert_eq!(gauge.load(Ordering::SeqCst), capacity,
               "pages leaked: gauge must return to full capacity");
}

// ---------------------------------------------------------------------
// Multi-reactor front end (ISSUE 9): lane-partitioned clients and the
// reactor fleet must be invisible to clients — per-request output is
// bit-identical to the single-reactor (and single-engine) baseline,
// streaming survives adversarial segmentation through a 2-reactor
// server, and the accept-handoff fallback (the path taken wherever
// SO_REUSEPORT is unavailable, and always for pre-bound listeners)
// round-trips every connection.
// ---------------------------------------------------------------------

fn lane_group(shards: usize, lanes: usize) -> EngineGroup<SimEngine> {
    EngineGroup::with_config(
        GroupConfig { shards, lanes, ..Default::default() },
        |_| Ok(SimEngine::new(SimConfig::default())),
    )
    .unwrap()
}

#[test]
fn run_lanes_matches_run_group_per_request() {
    let trace = mixed_trace(48, 7);
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };

    let base = {
        let mut group = sim_group(4);
        let out = by_id(runner.run_group(&mut group, &trace).unwrap());
        group.shutdown().unwrap();
        out
    };

    // Same 4-shard fleet, 4 lane views driven the way the multi-reactor
    // server partitions traffic: entry e submits through lane e % 4.
    let mut lanes = lane_group(4, 4).into_lanes();
    assert_eq!(lanes.len(), 4);
    let comps = by_id(runner.run_lanes(&mut lanes, &trace).unwrap());
    let primary = lanes.remove(0);
    drop(lanes); // secondary views drop; the primary owns shutdown
    let gm = primary.shutdown().unwrap();

    assert_eq!(comps, base, "4-lane replay diverged from 1-lane");
    assert_eq!(gm.fleet().requests_completed, 48);
}

#[test]
fn four_reactors_match_one_reactor_bit_identically_over_sockets() {
    let trace = mixed_trace(48, 7);
    let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
    let mut single = SimEngine::new(SimConfig::default());
    let base = by_id(runner.run(&mut single, &trace).unwrap());

    let mut outputs: Vec<BTreeMap<u64, (Vec<i32>, String)>> = Vec::new();
    for reactors in [1usize, 4] {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let group = lane_group(4, reactors);
        let cfg = ServeConfig { limit: Some(trace.len()), reactors,
                                ..Default::default() };
        let srv = std::thread::spawn(move || {
            server::serve_on(listener, group, cfg).unwrap();
        });

        // Four pipelined connections; with 4 reactors the round-robin
        // accept handoff spreads them one per reactor, so every reactor
        // parses, routes through its own lane, and streams replies.
        const CLIENTS: usize = 4;
        let mut conns: Vec<TcpStream> = (0..CLIENTS)
            .map(|_| TcpStream::connect(addr).unwrap())
            .collect();
        let mut sent = vec![0usize; CLIENTS];
        for (i, t) in trace.iter().enumerate() {
            let c = i % CLIENTS;
            writeln!(conns[c], "{}",
                     request_line(i, &t.episode.prompt, t.max_new))
                .unwrap();
            sent[c] += 1;
        }
        for c in &mut conns {
            c.flush().unwrap();
        }

        let mut got: BTreeMap<u64, (Vec<i32>, String)> = BTreeMap::new();
        for (c, conn) in conns.into_iter().enumerate() {
            let mut reader = BufReader::new(conn);
            for _ in 0..sent[c] {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = Json::parse(&line)
                    .unwrap_or_else(|_| panic!("bad {line:?}"));
                assert!(j.get("error").is_err(),
                        "reactors={reactors}: unexpected error {line:?}");
                let id = j.get("id").unwrap().as_i64().unwrap() as u64;
                let generated: Vec<i32> = j
                    .get("generated").unwrap().as_arr().unwrap()
                    .iter().map(|t| t.as_i64().unwrap() as i32).collect();
                let stop = j.get("stop").unwrap().as_str().unwrap().to_string();
                assert!(got.insert(id, (generated, stop)).is_none(),
                        "reactors={reactors}: duplicate reply for {id}");
            }
        }
        srv.join().unwrap();

        assert_eq!(got.len(), base.len(), "reactors={reactors}");
        for (id, (_plen, want_gen, want_stop)) in &base {
            let (gen, stop) = got.get(id).expect("missing reply");
            assert_eq!(gen, want_gen,
                       "reactors={reactors} request {id} diverged from the \
                        blocking baseline");
            assert_eq!(stop, want_stop.as_str(),
                       "reactors={reactors} request {id} stop reason");
        }
        outputs.push(got);
    }
    assert_eq!(outputs[0], outputs[1],
               "1-reactor and 4-reactor runs must be bit-identical");
}

#[test]
fn two_reactor_streaming_survives_adversarial_segmentation() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group = lane_group(2, 2);
    let cfg = ServeConfig { limit: Some(2), reactors: 2,
                            ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    let prompt = vec![6, 28, 496, 3];
    // First connection stays on reactor 0; the round-robin handoff
    // places the second on reactor 1 — the streaming request crosses
    // the eventfd wake path of a *different* reactor than the plain one.
    let mut plain = TcpStream::connect(addr).unwrap();
    writeln!(plain, "{}", request_line(10, &prompt, 24)).unwrap();
    plain.flush().unwrap();

    let mut stream = TcpStream::connect(addr).unwrap();
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let line = format!(
        "{{\"id\": 11, \"prompt\": [{}], \"max_new\": 24, \"stream\": true}}",
        toks.join(", "));
    write_segmented(&mut stream, &line, 3);

    let mut deltas: Vec<i32> = Vec::new();
    let mut reader = BufReader::new(stream);
    let terminal = loop {
        let mut l = String::new();
        assert!(reader.read_line(&mut l).unwrap() > 0,
                "EOF before terminal reply");
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
        assert!(j.get("error").is_err(), "unexpected error {l:?}");
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 11);
        if j.opt("stop").is_some() {
            break j;
        }
        assert_eq!(j.get("index").unwrap().as_i64().unwrap() as usize,
                   deltas.len(), "delta frames arrive in order");
        for t in j.get("delta").unwrap().as_arr().unwrap() {
            deltas.push(t.as_i64().unwrap() as i32);
        }
    };
    assert!(!deltas.is_empty(), "at least one delta before Finished");

    let mut plain_reader = BufReader::new(plain);
    let mut l = String::new();
    plain_reader.read_line(&mut l).unwrap();
    let j = Json::parse(&l).unwrap();
    assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 10);
    let plain_gen: Vec<i32> = j
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    srv.join().unwrap();

    let stream_gen: Vec<i32> = terminal
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    assert_eq!(deltas, stream_gen,
               "concatenated deltas != streaming terminal reply");
    assert_eq!(stream_gen, plain_gen,
               "streaming and non-streaming replies diverged across reactors");
    let (want, _) =
        SimEngine::expected_generation(&SimConfig::default(), &prompt, 24);
    assert_eq!(plain_gen, want, "both must equal the sim reference");
}

#[test]
fn prebound_listener_falls_back_to_accept_handoff_across_reactors() {
    // SO_REUSEPORT cannot be retrofitted onto a pre-bound listener, so
    // `serve_on` with reactors > 1 *always* takes the accept-handoff
    // fallback — the exact path used on kernels without the option.
    // Six sequential connections round-robin across three reactors
    // (0,1,2,0,1,2); each must round-trip one request, which requires
    // the handoff send + eventfd wake + adoption on the target reactor
    // to all work.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let group = lane_group(2, 3);
    let cfg = ServeConfig { limit: Some(6), reactors: 3,
                            ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    for i in 0..6usize {
        let prompt = vec![5, 6, 7 + i as i32];
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "{}", request_line(i, &prompt, 8)).unwrap();
        conn.flush().unwrap();
        let mut reader = BufReader::new(conn);
        let mut l = String::new();
        assert!(reader.read_line(&mut l).unwrap() > 0,
                "conn {i}: EOF instead of a reply");
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad {l:?}"));
        assert!(j.get("error").is_err(), "conn {i}: unexpected error {l:?}");
        assert_eq!(j.get("id").unwrap().as_i64().unwrap() as usize, i);
        let generated: Vec<i32> = j
            .get("generated").unwrap().as_arr().unwrap()
            .iter().map(|t| t.as_i64().unwrap() as i32).collect();
        let (want, _) = SimEngine::expected_generation(
            &SimConfig::default(), &prompt, 8);
        assert_eq!(generated, want, "conn {i} diverged");
    }
    srv.join().unwrap();
}

// ---------------------------------------------------------------------
// Parallel gather == serial gather over disjoint arena rows.
// ---------------------------------------------------------------------

mod gather_parity {
    use seerattn::coordinator::gather::{gather_dense_into, gather_one_dense,
                                        gather_one_sparse, gather_sparse_into,
                                        DenseGeom, GatherJob, GatherPool,
                                        SparseGeom};
    use seerattn::coordinator::StagingArena;
    use seerattn::kvcache::{PagedKvPool, SeqKv};
    use seerattn::sparse::policy::{SelKind, SelectionBuf};
    use seerattn::util::rng::Rng;

    const BS: usize = 4;
    const HKV: usize = 2;
    const H_ALL: usize = 4;
    const G: usize = H_ALL / HKV;
    const DH: usize = 3;
    const BATCH: usize = 5;

    struct World {
        pool: PagedKvPool,
        seqs: Vec<SeqKv>,
        sels: Vec<SelectionBuf>,
        rng: Rng,
    }

    impl World {
        fn new(seed: u64) -> World {
            let mut w = World {
                pool: PagedKvPool::new(BATCH * 20, HKV, DH, BS),
                seqs: (0..BATCH).map(|_| SeqKv::new()).collect(),
                sels: (0..BATCH).map(|_| SelectionBuf::new()).collect(),
                rng: Rng::new(seed),
            };
            for i in 0..BATCH {
                let t = w.rng.range(3, 30);
                for _ in 0..t {
                    let k: Vec<f32> =
                        (0..HKV * DH).map(|_| w.rng.normal() as f32).collect();
                    let v: Vec<f32> =
                        (0..HKV * DH).map(|_| w.rng.normal() as f32).collect();
                    w.seqs[i].append(&mut w.pool, &k, &v).unwrap();
                }
            }
            w
        }

        /// Fill slot `i`'s SelectionBuf with random ascending rows that
        /// include the (possibly partial) last block.
        fn randomize_selection(&mut self, i: usize, per_head: bool) {
            let nblk = self.seqs[i].n_blocks();
            let (kind, rows) = if per_head {
                (SelKind::PerHead, H_ALL)
            } else {
                (SelKind::Shared, HKV)
            };
            self.sels[i].begin(kind, rows);
            for r in 0..rows {
                let take = self.rng.range(1, nblk + 1);
                let mut picked = self.rng.sample_distinct(nblk, take);
                let last = nblk - 1;
                if !picked.contains(&last) {
                    picked.push(last);
                }
                picked.sort_unstable();
                let row = self.sels[i].row_mut(r);
                row.clear();
                row.extend(picked.into_iter().map(|b| b as i32));
            }
        }
    }

    #[test]
    fn sparse_parallel_gather_bit_identical_to_serial() {
        let mut w = World::new(301);
        let gpool = GatherPool::new(4);
        let mut serial_arena = StagingArena::new();
        let mut parallel_arena = StagingArena::new();
        for step in 0..25 {
            let per_head = step % 2 == 1;
            let heads = if per_head { H_ALL } else { HKV };
            let t_cap = 8 * BS;
            for i in 0..BATCH {
                w.randomize_selection(i, per_head);
            }
            let geom = SparseGeom { heads, group: G, per_head, block_size: BS,
                                    t_cap, dh: DH };
            let jobs: Vec<GatherJob> = (0..BATCH)
                .map(|i| GatherJob { row: i, kv: &w.seqs[i], sel: &w.sels[i] })
                .collect();

            let sset = serial_arena.sparse(BATCH, heads, t_cap, DH);
            {
                let (k, v, m, d) = sset.parts_mut();
                let row_kv = heads * t_cap * DH;
                let row_m = heads * t_cap;
                for job in &jobs {
                    let r = job.row;
                    gather_one_sparse(&w.pool, job, &geom,
                                      &mut k[r * row_kv..(r + 1) * row_kv],
                                      &mut v[r * row_kv..(r + 1) * row_kv],
                                      &mut m[r * row_m..(r + 1) * row_m],
                                      &mut d[r * heads..(r + 1) * heads]);
                }
            }
            let pset = parallel_arena.sparse(BATCH, heads, t_cap, DH);
            {
                let (k, v, m, d) = pset.parts_mut();
                gather_sparse_into(&w.pool, jobs.len(), &|i| jobs[i], &geom,
                                   k, v, m, d, Some(&gpool));
            }
            assert_eq!(pset.k.as_f32().unwrap(), sset.k.as_f32().unwrap(),
                       "k step={step}");
            assert_eq!(pset.v.as_f32().unwrap(), sset.v.as_f32().unwrap(),
                       "v step={step}");
            assert_eq!(pset.mask.as_f32().unwrap(), sset.mask.as_f32().unwrap(),
                       "mask step={step}");
            assert_eq!(pset.dirty(), sset.dirty(), "dirty step={step}");
        }
    }

    #[test]
    fn dense_parallel_gather_bit_identical_to_serial() {
        let w = World::new(302);
        let gpool = GatherPool::new(3);
        let s = 32;
        let geom = DenseGeom { hkv: HKV, block_size: BS, max_seq: s, dh: DH };
        let jobs: Vec<GatherJob> = (0..BATCH)
            .map(|i| GatherJob { row: i, kv: &w.seqs[i], sel: &w.sels[i] })
            .collect();
        let mut serial_arena = StagingArena::new();
        let mut parallel_arena = StagingArena::new();
        let sset = serial_arena.dense(BATCH, HKV, s, DH);
        {
            let (k, v, sl, d) = sset.parts_mut();
            let row_kv = HKV * s * DH;
            for job in &jobs {
                let r = job.row;
                gather_one_dense(&w.pool, job, &geom,
                                 &mut k[r * row_kv..(r + 1) * row_kv],
                                 &mut v[r * row_kv..(r + 1) * row_kv],
                                 &mut sl[r..r + 1],
                                 &mut d[r * HKV..(r + 1) * HKV]);
            }
        }
        let pset = parallel_arena.dense(BATCH, HKV, s, DH);
        {
            let (k, v, sl, d) = pset.parts_mut();
            gather_dense_into(&w.pool, jobs.len(), &|i| jobs[i], &geom,
                              k, v, sl, d, Some(&gpool));
        }
        assert_eq!(pset.k.as_f32().unwrap(), sset.k.as_f32().unwrap());
        assert_eq!(pset.v.as_f32().unwrap(), sset.v.as_f32().unwrap());
        assert_eq!(pset.seq_len.as_i32().unwrap(), sset.seq_len.as_i32().unwrap());
        assert_eq!(pset.dirty(), sset.dirty());
    }
}

// ---------------------------------------------------------------------
// Shard supervision (ISSUE 10): panic recovery with bit-identical
// request rescue, the chaos matrix with a Panic leg, the trace runner's
// bounded give-up, and the SIGTERM graceful drain.
// ---------------------------------------------------------------------

#[test]
fn shard_panic_mid_stream_rescues_bit_identical() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    // Every incarnation of the single shard panics at its own step 10,
    // so finishing the 20-token stream takes several crash + respawn +
    // rescue cycles. The client must see one gapless delta stream whose
    // concatenation equals the pure token function — no token repeated,
    // none lost — and the respawned engine's page pool must end at full
    // capacity.
    let sim_cfg = SimConfig {
        batch: 2,
        pages_per_slot: 8,
        page_tokens: 8,
        eos_every: 0,
        faults: FaultSchedule::none().at(10, Fault::Panic),
        ..Default::default()
    };
    let capacity = sim_cfg.batch * sim_cfg.pages_per_slot;
    let gauge = Arc::new(AtomicUsize::new(0));
    let factory_gauge = gauge.clone();
    let gcfg = GroupConfig {
        shards: 1,
        queue_depth: 8,
        restart_limit: 64,
        restart_backoff_ms: 1,
        rescue_limit: 64,
        ..Default::default()
    };
    let group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |_| {
            Ok(SimEngine::with_pool_gauge(sim_cfg, factory_gauge.clone()))
        })
        .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { limit: Some(2), ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // The streaming request first, so it is routed while the shard's
    // first incarnation is certainly alive.
    let prompt = vec![2, 4, 6];
    let stream_conn = TcpStream::connect(addr).unwrap();
    stream_conn
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    {
        let mut w = stream_conn.try_clone().unwrap();
        writeln!(w, "{{\"id\": 1, \"prompt\": [2, 4, 6], \"max_new\": 20, \
                     \"stream\": true}}")
            .unwrap();
        w.flush().unwrap();
    }
    let mut reader = BufReader::new(stream_conn);
    let mut first = String::new();
    assert!(reader.read_line(&mut first).unwrap() > 0, "EOF before deltas");

    // A short non-streaming co-resident racing the crash windows. A
    // submission landing in the brief dead-shard gap gets a structured
    // backpressure reply; retry like a well-behaved client.
    let plain = TcpStream::connect(addr).unwrap();
    plain.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let plain_prompt = vec![3, 5];
    let mut plain_reader = BufReader::new(plain.try_clone().unwrap());
    let plain_reply = loop {
        {
            let mut w = plain.try_clone().unwrap();
            writeln!(w, "{}", request_line(2, &plain_prompt, 2)).unwrap();
            w.flush().unwrap();
        }
        let mut l = String::new();
        assert!(plain_reader.read_line(&mut l).unwrap() > 0,
                "EOF before the plain reply");
        let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad frame {l:?}"));
        if j.get("error").is_ok() {
            assert!(j.get("retry_after_ms").is_ok(),
                    "only backpressure errors are acceptable: {l:?}");
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        break j;
    };

    // Drain the stream: every delta's index must equal the count of
    // tokens already seen — gapless and repeat-free across respawns.
    let mut deltas: Vec<i32> = Vec::new();
    let mut line = first;
    let terminal = loop {
        let j = Json::parse(&line)
            .unwrap_or_else(|_| panic!("bad frame {line:?}"));
        assert!(j.get("error").is_err(), "unexpected error {line:?}");
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 1);
        if j.opt("stop").is_some() {
            break j;
        }
        if j.opt("delta").is_some() {
            assert_eq!(j.get("index").unwrap().as_i64().unwrap() as usize,
                       deltas.len(),
                       "delta index gap across a shard crash: {line:?}");
            for t in j.get("delta").unwrap().as_arr().unwrap() {
                deltas.push(t.as_i64().unwrap() as i32);
            }
        }
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0,
                "EOF before the terminal reply");
    };
    srv.join().unwrap();

    let (want, want_stop) = SimEngine::expected_generation(&sim_cfg, &prompt, 20);
    let term_gen: Vec<i32> = terminal
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    assert_eq!(deltas, term_gen, "concatenated deltas != terminal reply");
    assert_eq!(term_gen, want,
               "crash + rescue broke the stream's bit-identity");
    assert_eq!(terminal.get("stop").unwrap().as_str().unwrap(),
               want_stop.as_str());
    let (want_plain, _) =
        SimEngine::expected_generation(&sim_cfg, &plain_prompt, 2);
    let plain_gen: Vec<i32> = plain_reply
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    assert_eq!(plain_gen, want_plain, "co-resident diverged");
    assert_eq!(gauge.load(Ordering::SeqCst), capacity,
               "the respawned pool must end at full capacity");
}

#[test]
fn chaos_with_panic_leg_never_loses_a_request() {
    // The ISSUE 6 chaos property with shard death in the matrix: on top
    // of the seeded stall/shrink/fail-admit schedule, every incarnation
    // of every shard panics at a seed-chosen step. With a generous
    // restart budget nothing may be lost, duplicated, or perturbed —
    // rescued-and-replayed streams equal the pure token function.
    for seed in chaos_seeds() {
        let n = 24usize;
        let trace = chaos_trace(n, seed);
        let sim_cfg = SimConfig {
            batch: 2,
            pages_per_slot: 4, // pool = 8 pages per shard
            page_tokens: 8,
            eos_every: 0,
            step_delay_ms: 1,
            preempt_retries: 2,
            faults: FaultSchedule::seeded(seed, 8)
                .at(18 + seed % 14, Fault::Panic),
            prefill_chunk: 8,
            ..Default::default()
        };
        let gcfg = GroupConfig {
            shards: 4,
            queue_depth: 2,
            restart_limit: 100,
            restart_backoff_ms: 1,
            rescue_limit: 100,
            ..Default::default()
        };
        let expect = trace.clone();
        let worker = std::thread::spawn(move || {
            let mut group: EngineGroup<SimEngine> =
                EngineGroup::with_config(gcfg,
                                         move |_| Ok(SimEngine::new(sim_cfg)))
                    .unwrap();
            let runner =
                TraceRunner { replay: Replay::Virtual, ..Default::default() };
            let comps = runner.run_group(&mut group, &trace).unwrap();
            let gm = group.shutdown().unwrap();
            (comps, gm)
        });
        let deadline = Instant::now() + Duration::from_secs(60);
        while !worker.is_finished() {
            assert!(Instant::now() < deadline,
                    "seed {seed}: panic-leg chaos replay deadlocked");
            std::thread::sleep(Duration::from_millis(10));
        }
        let (comps, gm) = worker.join().unwrap();
        let comps = by_id(comps); // also asserts no duplicated ids
        assert_eq!(comps.len(), n, "seed {seed}: a request was lost");
        for (id, (plen, generated, stop)) in &comps {
            let t = &expect[*id as usize];
            assert_eq!(*plen, t.episode.prompt.len(), "seed {seed} id {id}");
            let (want, want_stop) = SimEngine::expected_generation(
                &sim_cfg, &t.episode.prompt, t.max_new);
            match stop {
                StopReason::Eos | StopReason::MaxNewTokens
                | StopReason::ContextFull => {
                    assert_eq!(stop, &want_stop, "seed {seed} id {id}");
                    assert_eq!(generated, &want,
                               "seed {seed} id {id}: crash rescue broke \
                                bit-identity");
                }
                StopReason::ResourceExhausted => {
                    assert!(want.starts_with(generated),
                            "seed {seed} id {id}: exhausted completion \
                             diverged from the token function");
                }
                StopReason::Cancelled | StopReason::DeadlineExceeded => {
                    panic!("seed {seed} id {id}: stop {stop:?} without a \
                            cancel or deadline")
                }
            }
        }
        assert!(gm.supervision.restarts >= 1,
                "seed {seed}: the panic fault never landed");
    }
}

#[test]
fn trace_runner_gives_up_after_bounded_retries() {
    // Two long blockers saturate a 1-shard, capacity-2 admission window
    // for ~0.5s; the three followers hear `Rejected` on every attempt
    // and must stop after a 3-long streak (~15ms of client patience)
    // with structured `resource_exhausted` completions — the historical
    // retry-forever client would have waited the blockers out instead.
    let sim_cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 5,
                              ..Default::default() };
    let gcfg = GroupConfig { shards: 1, queue_depth: 1,
                             ..Default::default() };
    let mk = |prompt: Vec<i32>, max_new: usize| TracedRequest {
        arrival_s: 0.0,
        episode: Episode { prompt, target: Vec::new(), answer: 0,
                           cfg: TaskConfig::easy() },
        max_new,
    };
    let trace = vec![
        mk(vec![5, 9, 2], 100),
        mk(vec![6, 1, 3], 100),
        mk(vec![7, 7], 4),
        mk(vec![8, 2], 4),
        mk(vec![9, 4], 4),
    ];
    let mut group: EngineGroup<SimEngine> =
        EngineGroup::with_config(gcfg, move |_| Ok(SimEngine::new(sim_cfg)))
            .unwrap();
    let runner = TraceRunner { replay: Replay::Virtual,
                               give_up_after: Some(3),
                               ..Default::default() };
    let comps = by_id(runner.run_group(&mut group, &trace).unwrap());
    group.shutdown().unwrap();

    assert_eq!(comps.len(), trace.len(), "an entry was silently dropped");
    assert_eq!(runner.gave_up(), 3, "exactly the three followers give up");
    for id in [0u64, 1] {
        let (plen, generated, stop) = comps.get(&id).unwrap();
        let t = &trace[id as usize];
        let (want, want_stop) = SimEngine::expected_generation(
            &sim_cfg, &t.episode.prompt, t.max_new);
        assert_eq!(*plen, t.episode.prompt.len());
        assert_eq!(generated, &want, "blocker {id} diverged");
        assert_eq!(stop, &want_stop);
    }
    for id in [2u64, 3, 4] {
        let (plen, generated, stop) = comps.get(&id).unwrap();
        assert_eq!(*stop, StopReason::ResourceExhausted,
                   "give-up outcome must be structured, id {id}");
        assert!(generated.is_empty(), "nothing was ever generated");
        assert_eq!(*plen, trace[id as usize].episode.prompt.len());
    }
}

#[test]
fn sigterm_drains_gracefully_with_zero_dropped_requests() {
    extern "C" {
        fn raise(sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;

    let sim_cfg = SimConfig { batch: 2, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
    let group: EngineGroup<SimEngine> =
        EngineGroup::new(1, move |_| Ok(SimEngine::new(sim_cfg))).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // No completion limit: the only way this server exits is the
    // SIGTERM drain, so the join below is the clean-exit assertion.
    let cfg = ServeConfig { drain_on_signal: true, ..Default::default() };
    let srv = std::thread::spawn(move || {
        server::serve_on(listener, group, cfg).unwrap();
    });

    // A streaming request slow enough (~2ms x 80 steps) that the signal
    // lands mid-decode.
    let prompt = vec![2, 4, 6];
    let busy = TcpStream::connect(addr).unwrap();
    busy.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    {
        let mut w = busy.try_clone().unwrap();
        writeln!(w, "{{\"id\": 1, \"prompt\": [2, 4, 6], \"max_new\": 80, \
                     \"stream\": true}}")
            .unwrap();
        w.flush().unwrap();
    }
    let mut busy_reader = BufReader::new(busy.try_clone().unwrap());
    let mut first = String::new();
    assert!(busy_reader.read_line(&mut first).unwrap() > 0);
    assert!(Json::parse(&first).unwrap().get("delta").is_ok(),
            "expected a delta frame, got {first:?}");

    // An idle connection open across the drain; it must get a goodbye.
    let idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    std::thread::sleep(Duration::from_millis(30)); // let it be adopted

    unsafe { raise(SIGTERM) };

    // The idle connection's goodbye doubles as the "drain observed"
    // barrier: after it, new requests are deterministically refused.
    let mut idle_reader = BufReader::new(idle);
    let mut l = String::new();
    assert!(idle_reader.read_line(&mut l).unwrap() > 0,
            "idle connection closed without a goodbye");
    let j = Json::parse(&l).unwrap_or_else(|_| panic!("bad goodbye {l:?}"));
    assert!(j.get("error").unwrap().as_str().unwrap().contains("draining"),
            "goodbye must say why: {l:?}");
    let mut rest = String::new();
    assert_eq!(idle_reader.read_line(&mut rest).unwrap(), 0,
               "idle connection must be closed after the goodbye");

    // A request line arriving mid-drain is refused, not silently eaten.
    {
        let mut w = busy.try_clone().unwrap();
        writeln!(w, "{}", request_line(2, &[8, 8], 4)).unwrap();
        w.flush().unwrap();
    }

    // The in-flight stream still runs to its normal completion.
    let mut deltas: Vec<i32> = Vec::new();
    let mut refused = false;
    let mut line = first;
    let terminal = loop {
        let j = Json::parse(&line)
            .unwrap_or_else(|_| panic!("bad frame {line:?}"));
        if j.get("error").is_ok() {
            assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 2,
                       "only the mid-drain request may be refused: {line:?}");
            assert!(j.get("error").unwrap().as_str().unwrap()
                        .contains("draining"));
            refused = true;
        } else if j.opt("stop").is_some() {
            break j;
        } else if j.opt("delta").is_some() {
            assert_eq!(j.get("index").unwrap().as_i64().unwrap() as usize,
                       deltas.len(), "delta gap across the drain");
            for t in j.get("delta").unwrap().as_arr().unwrap() {
                deltas.push(t.as_i64().unwrap() as i32);
            }
        }
        line.clear();
        assert!(busy_reader.read_line(&mut line).unwrap() > 0,
                "EOF before the terminal reply");
    };
    assert!(refused, "the mid-drain request must get a structured refusal");

    // serve_on returning Ok is the exit-0 criterion; the drain must not
    // have dropped or truncated the in-flight request.
    srv.join().unwrap();
    let (want, want_stop) = SimEngine::expected_generation(&sim_cfg, &prompt, 80);
    let term_gen: Vec<i32> = terminal
        .get("generated").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    assert_eq!(deltas, term_gen, "concatenated deltas != terminal reply");
    assert_eq!(term_gen, want, "the drain truncated an in-flight stream");
    assert_eq!(terminal.get("stop").unwrap().as_str().unwrap(),
               want_stop.as_str());
}
