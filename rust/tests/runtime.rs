//! Runtime-level integration: manifest-driven calls, shape validation,
//! kernel executables vs Rust-computed references. Needs the `pjrt`
//! feature (and `make artifacts`; self-skips without the latter).
#![cfg(feature = "pjrt")]

use seerattn::harness;
use seerattn::runtime::{Arg, HostTensor, Runtime};
use seerattn::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !harness::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::load(&harness::artifacts_dir()).unwrap())
}

#[test]
fn call_validates_arity_and_shapes() {
    let Some(rt) = runtime() else { return };
    // lm_head expects (x, ln_f, head).
    let bad = HostTensor::zeros_f32(vec![1, 1]);
    assert!(rt.call("lm_head", &[Arg::Host(&bad)]).is_err(), "arity");
    let spec = rt.manifest.exe("lm_head").unwrap().clone();
    let x = HostTensor::zeros_f32(spec.args[0].shape.clone());
    let lnf = HostTensor::zeros_f32(spec.args[1].shape.clone());
    assert!(
        rt.call("lm_head", &[Arg::Host(&x), Arg::Host(&lnf), Arg::Host(&bad)]).is_err(),
        "shape"
    );
    assert!(rt.call("nonexistent", &[]).is_err());
}

#[test]
fn lm_head_computes_rmsnorm_matmul() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.exe("lm_head").unwrap().clone();
    let (b, d) = (spec.args[0].shape[0], spec.args[0].shape[1]);
    let v = spec.args[2].shape[1];
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let lnf = vec![1.0f32; d];
    let head: Vec<f32> = (0..d * v).map(|_| rng.normal() as f32 * 0.05).collect();
    let outs = rt
        .call(
            "lm_head",
            &[
                Arg::Host(&HostTensor::f32(vec![b, d], x.clone())),
                Arg::Host(&HostTensor::f32(vec![d], lnf)),
                Arg::Host(&HostTensor::f32(vec![d, v], head.clone())),
            ],
        )
        .unwrap();
    let got = outs[0].as_f32().unwrap();
    assert_eq!(outs[0].shape, vec![b, v]);
    // Rust reference: rmsnorm(x) @ head.
    let eps = 1e-5f32;
    for bi in 0..b {
        let row = &x[bi * d..(bi + 1) * d];
        let ms = row.iter().map(|a| a * a).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for vi in (0..v).step_by(97) {
            let mut dot = 0f32;
            for di in 0..d {
                dot += row[di] * inv * head[di * v + vi];
            }
            let g = got[bi * v + vi];
            assert!((dot - g).abs() < 2e-3 * (1.0 + g.abs()), "({bi},{vi}): {dot} vs {g}");
        }
    }
}

#[test]
fn kernel_bench_sparse_full_equals_dense() {
    let Some(rt) = runtime() else { return };
    // Smallest kbench point: sparse with ALL blocks selected vs dense.
    let Some(point) = rt
        .manifest
        .kbench_points
        .iter()
        .min_by_key(|p| p.seqlen * p.batch)
        .cloned()
    else {
        return;
    };
    let kb = &rt.manifest.kbench;
    let heads = kb.get("n_heads").unwrap().as_usize().unwrap();
    let hkv = kb.get("n_kv_heads").unwrap().as_usize().unwrap();
    let dh = kb.get("head_dim").unwrap().as_usize().unwrap();
    let bs = kb.get("block_size").unwrap().as_usize().unwrap();
    let (s, b, ksel) = (point.seqlen, point.batch, point.k_sel);
    let nblk = s / bs;
    let mut rng = Rng::new(5);
    let q = HostTensor::f32(vec![b, heads, dh],
                            (0..b * heads * dh).map(|_| rng.normal() as f32).collect());
    let k = HostTensor::f32(vec![b, hkv, s, dh],
                            (0..b * hkv * s * dh).map(|_| rng.normal() as f32).collect());
    let v = HostTensor::f32(vec![b, hkv, s, dh],
                            (0..b * hkv * s * dh).map(|_| rng.normal() as f32).collect());
    // Restrict the valid length to ksel blocks so the sparse kernel with
    // indices 0..ksel sees the whole valid cache.
    let valid = (ksel * bs) as i32;
    let sl = HostTensor::i32(vec![b], vec![valid; b]);
    let dense = rt
        .call(&point.dense, &[Arg::Host(&q), Arg::Host(&k), Arg::Host(&v), Arg::Host(&sl)])
        .unwrap();
    let mut idx = Vec::new();
    for _ in 0..b * hkv {
        idx.extend((0..ksel as i32).collect::<Vec<_>>());
    }
    let idx_t = HostTensor::i32(vec![b, hkv, ksel], idx);
    let sparse = rt
        .call(
            &point.sparse,
            &[Arg::Host(&q), Arg::Host(&k), Arg::Host(&v), Arg::Host(&idx_t), Arg::Host(&sl)],
        )
        .unwrap();
    let d0 = dense[0].as_f32().unwrap();
    let s0 = sparse[0].as_f32().unwrap();
    assert_eq!(d0.len(), s0.len());
    let _ = nblk;
    for (a, c) in d0.iter().zip(s0) {
        assert!((a - c).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {c}");
    }
}

#[test]
fn runtime_stats_accumulate() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.exe("lm_head").unwrap().clone();
    let x = HostTensor::zeros_f32(spec.args[0].shape.clone());
    let lnf = HostTensor::zeros_f32(spec.args[1].shape.clone());
    let head = HostTensor::zeros_f32(spec.args[2].shape.clone());
    rt.call("lm_head", &[Arg::Host(&x), Arg::Host(&lnf), Arg::Host(&head)]).unwrap();
    let st = rt.stats();
    assert_eq!(st.calls, 1);
    assert!(st.compile_s > 0.0);
    assert!(st.upload_bytes > 0 && st.download_bytes > 0);
}
