#!/usr/bin/env python3
# Crude Rust syntax sanity check for toolchain-less containers: verifies
# brace/paren/bracket balance, aware of strings, raw strings, char
# literals, lifetimes, and line/block comments. Not a parser - catches
# gross structural slips only. Usage: scripts/balance_check.py FILES...
import sys

def check(path):
    src = open(path).read()
    stack = []
    i, n = 0, len(src)
    line = 1
    state = 'code'  # code, str, rawstr, char, lcomment, bcomment
    raw_hashes = 0
    depth_block = 0
    pairs = {'}': '{', ')': '(', ']': '['}
    while i < n:
        c = src[i]
        if c == '\n':
            line += 1
        if state == 'code':
            if c == '/' and i+1 < n and src[i+1] == '/':
                state = 'lcomment'; i += 2; continue
            if c == '/' and i+1 < n and src[i+1] == '*':
                state = 'bcomment'; depth_block = 1; i += 2; continue
            if c == '"':
                state = 'str'; i += 1; continue
            if c == 'r' and i+1 < n and src[i+1] in '#"':
                j = i+1; h = 0
                while j < n and src[j] == '#':
                    h += 1; j += 1
                if j < n and src[j] == '"':
                    state = 'rawstr'; raw_hashes = h; i = j+1; continue
            if c == "'":
                # char literal or lifetime; char if closing quote within 3 (handle \x)
                j = i+1
                if j < n and src[j] == '\\':
                    k = src.find("'", j+1)
                    if k != -1 and k - i < 12:
                        i = k+1; continue
                elif j+1 < n and src[j+1] == "'":
                    i = j+2; continue
                # lifetime: skip
                i += 1; continue
            if c in '{([':
                stack.append((c, line))
            elif c in '})]':
                if not stack or stack[-1][0] != pairs[c]:
                    print(f"{path}:{line}: unmatched {c!r} (stack top {stack[-1] if stack else None})")
                    return False
                stack.pop()
            i += 1
        elif state == 'lcomment':
            if c == '\n':
                state = 'code'
            i += 1
        elif state == 'bcomment':
            if c == '/' and i+1 < n and src[i+1] == '*':
                depth_block += 1; i += 2; continue
            if c == '*' and i+1 < n and src[i+1] == '/':
                depth_block -= 1; i += 2
                if depth_block == 0:
                    state = 'code'
                continue
            i += 1
        elif state == 'str':
            if c == '\\':
                i += 2; continue
            if c == '"':
                state = 'code'
            i += 1
        elif state == 'rawstr':
            if c == '"' and src[i+1:i+1+raw_hashes] == '#'*raw_hashes:
                state = 'code'; i += 1 + raw_hashes; continue
            i += 1
    if stack:
        print(f"{path}: unclosed {stack[-3:]}")
        return False
    print(f"{path}: balanced")
    return True

ok = all([check(p) for p in sys.argv[1:]])
sys.exit(0 if ok else 1)
