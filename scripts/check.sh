#!/usr/bin/env bash
# Repo check gate: formatting, lints (deny warnings), and the offline test
# suite on the default feature set. Exits nonzero on any failure.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --check --manifest-path rust/Cargo.toml

echo "== cargo clippy (default features, -D warnings) =="
cargo clippy --manifest-path rust/Cargo.toml --all-targets -- -D warnings

echo "== cargo test -q (default features) =="
cargo test -q --manifest-path rust/Cargo.toml

# The pjrt feature compiles against the vendored xla API stub; build-check
# it so feature-gated code cannot rot, but skip when requested (e.g. very
# old toolchains).
if [[ "${SEERATTN_SKIP_PJRT_CHECK:-0}" != "1" ]]; then
  echo "== cargo check --features pjrt (API-stub build) =="
  cargo check --manifest-path rust/Cargo.toml --features pjrt --all-targets
fi

echo "check.sh: all green"
