#!/usr/bin/env bash
# Benchmark runner: executes the host-side benches with fixed seeds and
# rewrites BENCH_decode.json at the repo root. Exits nonzero on failure
# (including the decode bench's zero-steady-state-allocation and
# gather-parity assertions).
#
# `--smoke` (or SEERATTN_BENCH_SMOKE=1) runs every bench with minimal
# timed iterations: all correctness asserts still fire, timings are
# indicative only, and BENCH_decode.json is NOT rewritten. CI uses this
# so the bench binaries can never rot uncompiled.
set -euo pipefail
cd "$(dirname "$0")/.."

export SEERATTN_BENCH_SEED="${SEERATTN_BENCH_SEED:-17}"
if [[ "${1:-}" == "--smoke" ]]; then
  export SEERATTN_BENCH_SMOKE=1
fi
if [[ "${SEERATTN_BENCH_SMOKE:-0}" == "1" ]]; then
  echo "== smoke mode: asserts only, timings ignored, no JSON rewrite =="
fi
# SIMD dispatch: auto unless SEERATTN_SIMD=scalar pins the fallback.
# The decode bench records CPU features (avx2/fma/neon) and the resolved
# dispatch target in BENCH_decode.json's config.simd block, and measures
# simd-vs-scalar in the same run — so numbers stay comparable across
# machines and modes.
echo "== simd dispatch: ${SEERATTN_SIMD:-auto} =="

echo "== decode_hot_path (seed ${SEERATTN_BENCH_SEED}) =="
cargo bench --manifest-path rust/Cargo.toml --bench decode_hot_path

echo "== gate_overhead =="
cargo bench --manifest-path rust/Cargo.toml --bench gate_overhead

# Streaming lifecycle smoke: one {"stream": true} request through the
# real reactor + shard + SimEngine stack over a socket (asserts delta
# parity; cheap by construction, so it runs in --smoke too and the
# event path can never rot uncompiled).
echo "== serving_stream (streaming e2e smoke) =="
cargo bench --manifest-path rust/Cargo.toml --bench serving_stream

# The end-to-end coordinator bench needs the pjrt feature, a real xla
# backend in rust/vendor/xla, and `make artifacts`; opt in explicitly.
if [[ "${SEERATTN_PJRT_BENCH:-0}" == "1" ]]; then
  echo "== coordinator (pjrt) =="
  cargo bench --manifest-path rust/Cargo.toml --features pjrt --bench coordinator
else
  echo "== coordinator (pjrt) skipped: set SEERATTN_PJRT_BENCH=1 to run =="
fi

if [[ "${SEERATTN_BENCH_SMOKE:-0}" == "1" ]]; then
  echo "bench.sh: smoke done (BENCH_decode.json untouched)"
else
  echo "bench.sh: done; BENCH_decode.json updated"
fi
