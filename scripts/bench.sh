#!/usr/bin/env bash
# Benchmark runner: executes the host-side benches with fixed seeds and
# rewrites BENCH_decode.json at the repo root. Exits nonzero on failure
# (including the decode bench's zero-steady-state-allocation assertion).
set -euo pipefail
cd "$(dirname "$0")/.."

export SEERATTN_BENCH_SEED="${SEERATTN_BENCH_SEED:-17}"

echo "== decode_hot_path (seed ${SEERATTN_BENCH_SEED}; writes BENCH_decode.json) =="
cargo bench --manifest-path rust/Cargo.toml --bench decode_hot_path

echo "== gate_overhead =="
cargo bench --manifest-path rust/Cargo.toml --bench gate_overhead

# The end-to-end coordinator bench needs the pjrt feature, a real xla
# backend in rust/vendor/xla, and `make artifacts`; opt in explicitly.
if [[ "${SEERATTN_PJRT_BENCH:-0}" == "1" ]]; then
  echo "== coordinator (pjrt) =="
  cargo bench --manifest-path rust/Cargo.toml --features pjrt --bench coordinator
else
  echo "== coordinator (pjrt) skipped: set SEERATTN_PJRT_BENCH=1 to run =="
fi

echo "bench.sh: done; BENCH_decode.json updated"
